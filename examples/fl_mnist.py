"""End-to-end driver (deliverable b): federated training of the paper's
784-64-10 MLP over the simulated wireless MAC for a few hundred rounds.

Reproduces the paper's §V setup: U=10 workers, K̄ samples each, Rayleigh
block fading, P^Max=10mW, σ²=1e-4mW, top-κ sparsification + 1-bit CS +
analog aggregation, BIHT decoding, GD with α=0.1.

  PYTHONPATH=src python examples/fl_mnist.py --rounds 300 --agg obcsaa
  PYTHONPATH=src python examples/fl_mnist.py --agg perfect   # benchmark
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.obcsaa import OBCSAAConfig, comm_stats
from repro.data import load_mnist, partition_workers
from repro.fl import FederatedTrainer, FLConfig
from repro.models.mlp_mnist import (init_mlp_mnist, mlp_mnist_accuracy,
                                    mlp_mnist_loss, param_dim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--samples", type=int, default=3000)
    ap.add_argument("--agg", default="obcsaa",
                    choices=["obcsaa", "perfect", "topk_aa"])
    ap.add_argument("--scheduler", default="all",
                    choices=["all", "enum", "admm", "greedy",
                             "admm_batched", "greedy_batched"],
                    help="batched solvers run fused inside the scan "
                         "engine; enum/admm/greedy use the host "
                         "reference loop (DESIGN.md §11)")
    ap.add_argument("--kappa", type=int, default=80,
                    help="top-κ per 4096-chunk (80x13 ≈ paper κ=1000)")
    ap.add_argument("--measure", type=int, default=1024)
    ap.add_argument("--noise-var", type=float, default=1e-4)
    ap.add_argument("--noniid", action="store_true")
    args = ap.parse_args()

    xtr, ytr, xte, yte = load_mnist()
    wx, wy = partition_workers(xtr, ytr, args.workers, args.samples,
                               iid=not args.noniid, seed=0)
    worker_data = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    params0 = init_mlp_mnist(jax.random.PRNGKey(0))
    print(f"model D = {param_dim(params0)} (paper: 50890)")

    xe, ye = jnp.asarray(xte), jnp.asarray(yte)

    @jax.jit
    def eval_fn(p):
        return mlp_mnist_loss(p, xe, ye), mlp_mnist_accuracy(p, xe, ye)

    def loss_fn(p, data):
        return mlp_mnist_loss(p, data["x"], data["y"])

    ob = OBCSAAConfig(chunk=4096, measure=args.measure, topk=args.kappa,
                      biht_iters=30, noise_var=args.noise_var)
    st = comm_stats(ob, param_dim(params0))
    print(f"per-round uplink: {st['symbols_per_round']} analog symbols "
          f"({st['compression_ratio']:.1f}x compression, "
          f"latency fraction {st['latency_fraction']:.3f})")

    cfg = FLConfig(aggregator=args.agg, scheduler=args.scheduler,
                   learning_rate=0.1, rounds=args.rounds, eval_every=10,
                   obcsaa=ob)
    tr = FederatedTrainer(cfg, loss_fn, params0, worker_data,
                          np.full(args.workers, float(args.samples)),
                          eval_fn=eval_fn)
    tr.run(verbose=True)
    final = tr.logs[-1]
    print(f"\nFINAL [{args.agg}/{args.scheduler}] "
          f"round={final.round} loss={final.loss:.4f} "
          f"accuracy={final.accuracy:.4f}")


if __name__ == "__main__":
    main()
