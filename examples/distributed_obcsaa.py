"""OBCSAA as a first-class distributed-training feature: train a reduced
gemma2 on an 8-device host mesh where each data shard is an FL worker and
gradient aggregation happens "over the air" (psum + AWGN + BIHT decode).

  PYTHONPATH=src python examples/distributed_obcsaa.py --steps 5
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_smoke_config
from repro.data import token_stream
from repro.launch import steps as steps_lib
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--agg", default="obcsaa", choices=["obcsaa", "mean"])
    args = ap.parse_args()

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(aggregation=args.agg, cs_chunk=1024, cs_measure=256,
                       cs_topk=64, biht_iters=10, learning_rate=0.02)
    print(f"mesh: {dict(mesh.shape)}  workers = data axis = 4  "
          f"tensor-parallel = model axis = 2")
    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt = steps_lib.make_optimizer(tcfg)
        ostate = opt.init(params)
        step = jax.jit(steps_lib.make_train_step(model, tcfg, mesh),
                       donate_argnums=(0, 1))
        toks, tgts = token_stream(8, 64, cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        for t in range(args.steps):
            ctx = steps_lib.default_round_ctx(mesh, seed=t)
            t0 = time.time()
            params, ostate, m = step(params, ostate, batch, ctx)
            print(f"step {t}: loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.2f}s)")


if __name__ == "__main__":
    main()
