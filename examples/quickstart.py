"""Quickstart: the OBCSAA pipeline in 40 lines.

Compress a gradient with 1-bit CS (eq. 6-7), aggregate 8 workers over a
simulated fading MAC (eq. 8-13), reconstruct with BIHT (eq. 43), and compare
against the error-free average.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import OBCSAAConfig, comm_stats, simulate_round

U, D = 8, 16384
cfg = OBCSAAConfig(chunk=4096, measure=1024, topk=200, biht_iters=30)

# workers share a common signal + disagreement noise (typical FL gradients)
key = jax.random.PRNGKey(0)
base = jnp.zeros((D,)).at[jax.random.choice(key, D, (300,),
                                            replace=False)].set(
    jax.random.normal(jax.random.PRNGKey(1), (300,)))
grads = base[None] + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (U, D))

k_weights = jnp.full((U,), 3000.0)       # K_i samples per worker
beta = jnp.ones((U,))                    # all workers scheduled
h = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (U,))) + 1e-3
b_t = jnp.min(h * jnp.sqrt(10.0) / k_weights)   # eq. 11 power boundary

ghat, diag = simulate_round(cfg, grads, k_weights, beta, b_t, h,
                            jax.random.PRNGKey(4))
gbar = jnp.mean(grads, axis=0)
cos = jnp.dot(ghat, gbar) / (jnp.linalg.norm(ghat) * jnp.linalg.norm(gbar))

stats = comm_stats(cfg, D)
print(f"workers={U}  D={D}  symbols/round={stats['symbols_per_round']}  "
      f"compression={stats['compression_ratio']:.1f}x")
print(f"cosine(ĝ, ḡ) = {float(cos):.4f}")
print(f"||ĝ|| = {float(jnp.linalg.norm(ghat)):.3f}   "
      f"||ḡ|| = {float(jnp.linalg.norm(gbar)):.3f}")
