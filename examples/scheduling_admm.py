"""Joint worker-scheduling + power-control optimization (paper §IV).

Two views of P2 (DESIGN.md §10):

1. Single instance — Algorithm 1 (enumeration), Algorithm 2 (ADMM) and the
   greedy prefix solver through the ``repro.sched`` registry, with the
   O(2^U) vs O(U) scaling the paper's Remark 2 is about.
2. The fleet path — a time-correlated fading scenario generates channels
   for thousands of cells and ONE device call schedules every cell's round
   with the batched ADMM / vectorized greedy solvers.

  PYTHONPATH=src python examples/scheduling_admm.py --workers 12 --cells 4096
"""
import argparse
import time

import jax
import numpy as np

from repro.theory import AnalysisConstants
from repro.sched import (Problem, ScenarioConfig, admm_solve_batched,
                         generate, greedy_solve_batched, round_problems,
                         schedule)

CONST = AnalysisConstants(rho1=200.0, G=1.0)


def single_instance(U: int, seed: int):
    rng = np.random.default_rng(seed)
    prob = Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                   k_weights=np.full(U, 3000.0), p_max=10.0, noise_var=1e-4,
                   D=50890, S=1000, kappa=1000, const=CONST)
    print(f"U={U} channels: {np.round(prob.h, 3)}")
    for name, method in [("enumeration (Alg.1)", "enum"),
                         ("ADMM (Alg.2)", "admm"),
                         ("greedy prefix", "greedy"),
                         ("ADMM batched (B=1)", "admm_batched"),
                         ("greedy batched (B=1)", "greedy_batched")]:
        if method == "enum" and U > 16:
            print(f"{name:22s} skipped (2^{U} infeasible — paper Remark 2)")
            continue
        t0 = time.time()
        beta, bt, rt = schedule(prob, method)
        dt = time.time() - t0
        print(f"{name:22s} R_t={rt:.4f} b_t={bt:.3e} "
              f"scheduled={int(beta.sum())}/{U} ({dt*1e3:.1f} ms)")


def fleet(cells: int, U: int, seed: int):
    """Schedule `cells` cells' current round in one device call each."""
    print(f"\nfleet: {cells} cells x {U} workers, Gauss-Markov fading")
    scn = ScenarioConfig(rounds=4, cells=cells, workers=U, corr=0.9,
                         shadowing_db=6.0)
    traj = generate(scn, jax.random.PRNGKey(seed))       # (rounds, cells, U)
    # noisier uplink than the paper's §V point so the scheduling tradeoff
    # bites and the per-cell schedules differ
    prob = round_problems(traj, 0, k_weights=3000.0, p_max=10.0,
                          noise_var=10.0, D=50890, S=1000, kappa=1000,
                          const=AnalysisConstants(rho1=100.0, G=2.0))
    for name, solver in [("greedy_batched", greedy_solve_batched),
                         ("admm_batched", admm_solve_batched)]:
        jax.block_until_ready(solver(prob))              # compile
        t0 = time.time()
        beta, b_t, r = jax.block_until_ready(solver(prob))
        dt = time.time() - t0
        n = np.asarray(beta.sum(-1))
        print(f"{name:16s} {cells} cells in {dt*1e3:7.1f} ms "
              f"({cells/dt:,.0f} schedules/s)  scheduled/cell: "
              f"min={int(n.min())} mean={n.mean():.1f} max={int(n.max())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=12)
    ap.add_argument("--cells", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    single_instance(args.workers, args.seed)
    fleet(args.cells, max(args.workers, 16), args.seed)


if __name__ == "__main__":
    main()
