"""Joint worker-scheduling + power-control optimization demo (paper §IV).

Solves one round's P2 with Algorithm 1 (enumeration), Algorithm 2 (ADMM) and
the greedy prefix solver, and shows the O(2^U) vs O(U) scaling.

  PYTHONPATH=src python examples/scheduling_admm.py --workers 12
"""
import argparse
import time

import numpy as np

from repro.core.error_floor import AnalysisConstants
from repro.core.scheduling import (Problem, admm_solve, enumerate_solve,
                                   greedy_solve)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)
    U = args.workers
    prob = Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                   k_weights=np.full(U, 3000.0), p_max=10.0, noise_var=1e-4,
                   D=50890, S=1000, kappa=1000,
                   const=AnalysisConstants(rho1=200.0, G=1.0))
    print(f"U={U} channels: {np.round(prob.h, 3)}")
    for name, solver in [("enumeration (Alg.1)", enumerate_solve),
                         ("ADMM (Alg.2)", admm_solve),
                         ("greedy prefix", greedy_solve)]:
        if "enum" in name and U > 16:
            print(f"{name:22s} skipped (2^{U} infeasible — paper Remark 2)")
            continue
        t0 = time.time()
        beta, bt, rt = solver(prob)
        dt = time.time() - t0
        print(f"{name:22s} R_t={rt:.4f} b_t={bt:.3e} "
              f"scheduled={int(beta.sum())}/{U} ({dt*1e3:.1f} ms)")
    # scaling demonstration for ADMM
    for big_u in (64, 256, 1024):
        prob_b = Problem(h=np.abs(rng.normal(size=big_u)) + 1e-3,
                         k_weights=np.full(big_u, 3000.0), p_max=10.0,
                         noise_var=1e-4, D=50890, S=1000, kappa=1000,
                         const=AnalysisConstants(rho1=200.0, G=1.0))
        t0 = time.time()
        beta, bt, rt = admm_solve(prob_b)
        print(f"ADMM U={big_u:5d}: {1e3*(time.time()-t0):7.1f} ms "
              f"scheduled={int(beta.sum())}")


if __name__ == "__main__":
    main()
