"""Engine throughput + parity: scan×vmap engine vs the legacy host loop
(DESIGN.md §11).

The question of ISSUE 4: how fast does the 16-arm × 50-round MNIST-MLP
sweep run as ONE compiled scan-over-rounds × vmap-over-arms program,
against the pre-engine system that drove the paper-figure scripts — a
per-round host loop (one jit dispatch per pipeline stage, NumPy channel
draws, a host scheduling round trip, eager optimizer update) constructed
fresh per arm?

Methodology:

- ``LegacyTrainer`` is the PR-3-era ``fl/rounds.py:FederatedTrainer``
  vendored verbatim — host orchestration AND the PR-3 numerical kernels
  it ran on (the threshold-plus-cumsum top-κ selection this PR replaced
  with an index-scatter after XLA CPU fused the cumsum into an O(chunk²)
  reduce-window). Scheduling still flows through the LIVE registry, so
  the baseline *understates* the replaced system. Its per-arm jits are
  instance closures and its aggregation jit treats σ² as static, so a
  sweep RE-TRACES every arm, every sweep — per-arm wall (construction +
  compile + rounds) is the architecture's steady state, timed over
  ``LEGACY_SAMPLE`` arms and extrapolated to the grid.
- ``live_math=True`` reruns the same legacy loop on top of today's
  library (fast selection), isolating the orchestration-only gain —
  reported as ``speedup_vs_live_legacy`` alongside the headline.
- CI asserts the deterministic parity flags, not the load-sensitive
  ratio (the PR-3 convention): engine scan ≡ host reference loop bitwise
  at float32 (params + EF residual + decode warm-start carry) over
  ``PARITY_ROUNDS`` rounds with warm start + error feedback on, the
  per-round scheduling trajectory is dense (one entry per round), and
  the SPMD bisection budget (``OBCSAAConfig.bisect_iters``) leaves the
  training trajectory bit-identical to the 40-iteration default.

Gate (recorded in experiments/EXPERIMENTS.md): engine ≥ 20× legacy
host-loop throughput on the 16-arm × 50-round sweep, error feedback +
warm start on, ADMM (Algorithm 2) scheduling every round.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chan
from repro.theory import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig, simulate_round
from repro.core.quantize import sign_pm1
from repro.core.sparsify import flatten_pytree
from repro.data import load_mnist, partition_workers
from repro.engine import EngineRun, FLConfig, make_arms
from repro.fl import FederatedTrainer
from repro.fl.server import schedule_round
from repro.fl.worker import stacked_local_gradients
from repro.models.mlp_mnist import init_mlp_mnist, mlp_mnist_loss
from repro.optim.optimizers import sgd

A_ARMS, ROUNDS = 16, 50            # the acceptance-gate sweep shape
U, K = 4, 4                        # workers × samples (throughput config)
PARITY_ROUNDS = 12
LEGACY_SAMPLE = 2                  # legacy arms timed; extrapolated to A
BISECT_ITERS = 20                  # SPMD budget checked vs the 40 default
CONST = AnalysisConstants(rho1=200.0, G=1.0)

SWEEP_SEEDS = [0, 1, 2, 3] * 4
SWEEP_NOISE = [1e-6] * 4 + [1e-5] * 4 + [1e-4] * 4 + [1e-3] * 4


# --- PR-3 numerical kernels, vendored verbatim ------------------------------------
# (threshold + cumsum tie-break selection; XLA CPU fuses the cumsum into
# an O(chunk²) reduce-window — the perf bug this PR's index-scatter fixed)

def _pr3_topk_sparsify(g, k):
    absg = jnp.abs(g)
    kth = jax.lax.top_k(absg, k)[0][..., -1]
    mask = absg >= kth[..., None]
    over = jnp.cumsum(mask, axis=-1) <= k
    mask = mask & over
    return g * mask, mask


def _pr3_hard_threshold(x, k):
    absx = jnp.abs(x)
    kth = jax.lax.top_k(absx, k)[0][..., -1:]
    mask = absx >= kth
    over = jnp.cumsum(mask, axis=-1) <= k
    return x * (mask & over)


def _pr3_iht(y, phi, k, iters, tau, x0=None):
    def step(x, _):
        resid = y - jnp.einsum("sd,...d->...s", phi, x)
        x = x + tau * jnp.einsum("sd,...s->...d", phi, resid)
        return _pr3_hard_threshold(x, k), None

    if x0 is None:
        x0 = jnp.zeros(y.shape[:-1] + (phi.shape[1],), y.dtype)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def _pr3_simulate_round(ob: OBCSAAConfig, grads_flat, k_weights, beta, b_t,
                        h, key, decode_x0=None):
    """PR-3 ``simulate_round`` math on the PR-3 selection kernels:
    compress (eq. 6-7) → MAC + AWGN (eq. 12) → post-process (eq. 13) →
    fixed-step IHT decode (eq. 43) with magnitude tracking."""
    U_, D_ = grads_flat.shape
    pad = (-D_) % ob.chunk
    gpad = jnp.pad(grads_flat, ((0, 0), (0, pad)))
    phi = ob.phi()

    def compress(flat):
        gc = flat.reshape(-1, ob.chunk)
        sparse, _ = _pr3_topk_sparsify(gc, ob.topk)
        signs = sign_pm1(jnp.einsum("sd,nd->ns", phi, sparse))
        return signs, jnp.linalg.norm(sparse, axis=-1)

    signs, mags = jax.vmap(compress)(gpad)
    w = k_weights * beta * b_t
    y = jnp.einsum("u,ucs->cs", w.astype(signs.dtype), signs)
    y = y + chan.draw_noise(key, y.shape, ob.noise_var)
    denom = jnp.maximum(jnp.sum(k_weights * beta) * b_t, 1e-12)
    y = y / denom
    mbar = jnp.einsum("u,uc->c", (k_weights * beta).astype(mags.dtype),
                      mags) / jnp.maximum(jnp.sum(k_weights * beta), 1e-12)
    xhat = _pr3_iht(y, phi, ob.decode_k, ob.biht_iters, ob.recon_tau,
                    x0=decode_x0)
    raw = xhat
    if ob.magnitude_tracking:
        norm = jnp.linalg.norm(xhat, axis=-1, keepdims=True)
        xhat = xhat * (mbar[:, None] / jnp.maximum(norm, 1e-12))
    return xhat.reshape(-1)[:D_], raw


# --- the replaced host loop, vendored verbatim (PR-3 fl/rounds.py) ----------------

class LegacyTrainer:
    """The pre-engine host loop: per-round np.abs(rng.normal) channel
    draws, registry scheduling with a host round trip, one jit per
    pipeline stage, eager unflatten + optimizer update, and a host-synced
    ``np.array_equal`` warm-start reset. ``live_math=False`` (the
    baseline) additionally runs the PR-3 selection/threshold kernels;
    ``live_math=True`` runs the same loop on today's library. Kept
    verbatim as the benchmark baseline — do not modernize."""

    def __init__(self, cfg, loss_fn, params, worker_data, k_weights,
                 live_math: bool = False):
        self.cfg = cfg
        self.live_math = live_math
        self.params = params
        self.worker_data = worker_data
        self.k_weights = np.asarray(k_weights, np.float64)
        self.opt = sgd()
        self.opt_state = self.opt.init(params)
        flat, self._unflatten = flatten_pytree(params)
        self.D = int(flat.shape[0])
        self._rng = np.random.default_rng(cfg.seed)
        self._grad_fn = jax.jit(functools.partial(stacked_local_gradients,
                                                  loss_fn))
        self._agg_fn = jax.jit(self._aggregate)
        U_ = len(self.k_weights)
        ob = cfg.obcsaa
        self._n_chunks = -(-self.D // ob.chunk)
        self._decode_x0 = (jnp.zeros((self._n_chunks, ob.chunk))
                           if ob.warm_start else None)
        self._prev_beta = None
        self._residual = jnp.zeros((U_, self.D)) if cfg.error_feedback \
            else None
        if cfg.error_feedback:
            from repro.core.sparsify import topk_sparsify_chunked
            pad = self._n_chunks * ob.chunk - self.D

            def sparsify(g):
                if live_math:
                    return topk_sparsify_chunked(g, ob.topk, ob.chunk)[0]
                return _pr3_topk_sparsify(g.reshape(-1, ob.chunk),
                                          ob.topk)[0].reshape(g.shape)

            @jax.jit
            def ef_split(grads, residual):
                corrected = grads + residual
                gp = jnp.pad(corrected, ((0, 0), (0, pad)))
                sp = jax.vmap(sparsify)(gp)[:, :self.D]
                return corrected, corrected - sp

            self._ef_split = ef_split

    def _aggregate(self, grads_flat, k_weights, beta, b_t, h, key,
                   decode_x0=None):
        ob = self.cfg.obcsaa
        if self.live_math:
            ghat, diag = simulate_round(ob, grads_flat, k_weights, beta,
                                        b_t, h, key, decode_x0=decode_x0)
            return ghat, (diag["decode_xhat"] if ob.warm_start else None)
        ghat, xraw = _pr3_simulate_round(ob, grads_flat, k_weights, beta,
                                         b_t, h, key, decode_x0=decode_x0)
        return ghat, (xraw if ob.warm_start else None)

    def run_round(self, t: int):
        cfg = self.cfg
        U_ = len(self.k_weights)
        h = np.abs(self._rng.normal(size=U_))
        h = np.maximum(h, chan.H_MIN)
        beta, b_t = schedule_round(cfg.scheduler, h, self.k_weights,
                                   cfg.obcsaa, cfg.const, self.D)
        grads = self._grad_fn(self.params, self.worker_data)
        if self._residual is not None:
            grads, self._residual = self._ef_split(grads, self._residual)
        if (self._decode_x0 is not None and self._prev_beta is not None
                and not np.array_equal(beta, self._prev_beta)):
            self._decode_x0 = jnp.zeros_like(self._decode_x0)
        key = jax.random.PRNGKey(cfg.seed * 100003 + t)
        ghat, xraw = self._agg_fn(grads,
                                  jnp.asarray(self.k_weights, jnp.float32),
                                  jnp.asarray(beta, jnp.float32),
                                  jnp.asarray(b_t, jnp.float32),
                                  jnp.asarray(h, jnp.float32), key,
                                  self._decode_x0)
        if self._decode_x0 is not None:
            self._decode_x0 = xraw
        self._prev_beta = np.asarray(beta).copy()
        g_tree = self._unflatten(ghat[:self.D])
        self.params, self.opt_state = self.opt.update(
            g_tree, self.opt_state, self.params, cfg.learning_rate)

    def run(self, rounds: int):
        for t in range(rounds):
            self.run_round(t)


# --- setup ------------------------------------------------------------------------

def _task():
    xtr, ytr, _, _ = load_mnist()
    wx, wy = partition_workers(xtr, ytr, U, K, seed=0)
    wd = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    params0 = init_mlp_mnist(jax.random.PRNGKey(0))

    def loss_fn(p, d):
        return mlp_mnist_loss(p, d["x"], d["y"])

    return wd, params0, loss_fn


def _obcsaa(spmd: bool = False, bisect_iters: int = 40) -> OBCSAAConfig:
    return OBCSAAConfig(chunk=4096, measure=16, topk=8, biht_iters=2,
                        recon_alg="iht", recon_tau=0.25, warm_start=True,
                        spmd_topk=spmd, bisect_iters=bisect_iters)


def _cfg(spmd: bool = False, ef: bool = True,
         bisect_iters: int = 40) -> FLConfig:
    """The sweep runs the paper's own Algorithm 2 scheduler (ADMM) with
    error feedback on — the P2 solve and the beyond-paper EF arm are
    exactly what the engine makes sweepable (ISSUE 4 motivation). Inside
    the engine the ADMM inlines as the scan-safe
    ``admm_solve_batched_jit``; the legacy loop reaches the same solver
    through its per-round host registry round trip."""
    return FLConfig(aggregator="obcsaa", scheduler="admm_batched",
                    rounds=ROUNDS, obcsaa=_obcsaa(spmd, bisect_iters),
                    const=CONST, error_feedback=ef)


# --- throughput -------------------------------------------------------------------

def _legacy_arm(cfg, wd, params0, loss_fn, a: int,
                live_math: bool = False) -> float:
    """Wall for one legacy arm: construction + trace + ROUNDS rounds.
    Fresh trainers per arm is the architecture under test — its jits are
    instance closures and σ² is static in its aggregation jit, so nothing
    amortizes across arms (or across sweeps)."""
    c = dataclasses.replace(
        cfg, seed=SWEEP_SEEDS[a],
        obcsaa=dataclasses.replace(cfg.obcsaa, noise_var=SWEEP_NOISE[a]))
    t0 = time.time()
    tr = LegacyTrainer(c, loss_fn, params0, wd, np.full(U, float(K)),
                       live_math=live_math)
    tr.run(ROUNDS)
    jax.block_until_ready(tr.params)
    return time.time() - t0


def _time_pair(ecfg, lcfg, wd, params0, loss_fn):
    """Interleaved best-of timing (the sched_bench methodology): each
    trial alternates one legacy arm with one full engine sweep, so
    transient contention on the 2-core container hits both sides; the min
    over trials estimates each side's uncontended speed. The legacy
    per-arm min is extrapolated to the A-arm grid (UNDERSTATES the legacy
    wall — conservative for the speedup claim)."""
    arms = make_arms(ecfg, seeds=SWEEP_SEEDS, noise_var=SWEEP_NOISE)
    eng = EngineRun(ecfg, loss_fn, params0, wd, np.full(U, float(K)))

    def sweep():
        out = eng.run_sweep(arms, rounds=ROUNDS, eval_every=None)
        jax.block_until_ready(out["state"].params)

    t0 = time.time()
    sweep()                                # compile + first sweep
    cold = time.time() - t0
    warm, per_arm = np.inf, []
    for a in range(LEGACY_SAMPLE):
        per_arm.append(_legacy_arm(lcfg, wd, params0, loss_fn, a))
        t0 = time.time()
        sweep()
        warm = min(warm, time.time() - t0)
    return cold, warm, float(np.min(per_arm)) * A_ARMS


# --- parity -----------------------------------------------------------------------

def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _bisect_budget_parity(wd, params0, loss_fn, rounds: int = 20) -> bool:
    """Training at the reduced SPMD bisection budget must be bit-identical
    to the 40-iteration default over the parity horizon: on f32 gradients
    the kth-magnitude gap sits far above max·2^-20, so the shorter
    bracket resolves the same selection (16 demonstrably does not)."""
    kw = np.full(U, float(K))
    outs = []
    for it in (BISECT_ITERS, 40):
        cfg = dataclasses.replace(
            _cfg(spmd=True, bisect_iters=it), rounds=rounds)
        tr = FederatedTrainer(cfg, loss_fn, params0, wd, kw)
        tr.run(rounds)
        outs.append(tr.params)
    return _tree_equal(*outs)


def parity_flags(wd, params0, loss_fn):
    """Deterministic invariants for the CI smoke: scan engine ≡ host
    reference loop bitwise (params, EF residual, decode warm-start) with
    warm start + EF on, and dense per-round scheduling trajectories."""
    cfg = dataclasses.replace(_cfg(), rounds=PARITY_ROUNDS)
    kw = np.full(U, float(K))
    scan_tr = FederatedTrainer(cfg, loss_fn, params0, wd, kw)
    scan_tr.run(PARITY_ROUNDS)
    host_tr = FederatedTrainer(dataclasses.replace(cfg, mode="host"),
                               loss_fn, params0, wd, kw)
    host_tr.run(PARITY_ROUNDS)
    bitwise = (_tree_equal(scan_tr.params, host_tr.params)
               and _tree_equal(scan_tr._state.residual,
                               host_tr._state.residual)
               and _tree_equal(scan_tr._state.decode_x0,
                               host_tr._state.decode_x0))
    dense = (len(scan_tr.sched_logs) == PARITY_ROUNDS
             and len(host_tr.sched_logs) == PARITY_ROUNDS
             and [s.round for s in scan_tr.sched_logs]
             == list(range(PARITY_ROUNDS)))
    return bitwise, dense


# --- suite ------------------------------------------------------------------------

def main() -> List[tuple]:
    wd, params0, loss_fn = _task()

    bitwise, dense = parity_flags(wd, params0, loss_fn)
    rows = [(f"engine/parity_R{PARITY_ROUNDS}", 0.0,
             f"scan_vs_host_bitwise={bitwise};traj_dense={dense};"
             "warm_start=True;error_feedback=True")]

    bis_ok = _bisect_budget_parity(wd, params0, loss_fn)
    rows.append((f"engine/bisect_budget_{BISECT_ITERS}", 0.0,
                 f"params_bitwise_vs_40iters={bis_ok}"))

    cold, warm, t_legacy = _time_pair(_cfg(), _cfg(), wd, params0, loss_fn)
    n = A_ARMS * ROUNDS
    rows.append((f"engine/sweep_A{A_ARMS}_R{ROUNDS}", warm / n * 1e6,
                 f"rate={n / warm:.0f}rounds/s;cold={cold:.1f}s;"
                 f"warm={warm:.1f}s"))
    rows.append((f"engine/legacy_pr3_A{A_ARMS}_R{ROUNDS}",
                 t_legacy / n * 1e6,
                 f"rate={n / t_legacy:.1f}rounds/s;extrapolated_from="
                 f"{LEGACY_SAMPLE}arms"))
    rows.append((f"engine/speedup_A{A_ARMS}_R{ROUNDS}", warm * 1e6,
                 f"speedup={t_legacy / warm:.1f}x;gate>=20x"))

    t_live = min(_legacy_arm(_cfg(), wd, params0, loss_fn, a,
                             live_math=True)
                 for a in range(LEGACY_SAMPLE)) * A_ARMS
    rows.append((f"engine/speedup_vs_live_legacy_A{A_ARMS}_R{ROUNDS}",
                 warm * 1e6,
                 f"speedup={t_live / warm:.1f}x;"
                 "same_loop_on_todays_library=orchestration_only"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    main()
