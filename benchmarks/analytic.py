"""Analytic per-device FLOP and HBM-byte estimators for the roofline.

XLA's aggregate ``cost_analysis()`` counts while-loop (scan) bodies once, so
for scanned layer stacks it undercounts by ~num_layers. Collective bytes are
recovered exactly from the HLO with trip-count scaling (dryrun.py); compute
and memory use the napkin models below (assumptions documented in
EXPERIMENTS.md §Roofline).

Conventions:
  train   : fwd+bwd = 6·N_active·T, remat adds one fwd (=> 8·N·T) +
            quadratic attention terms (full-score blockwise impl, no causal
            skip at baseline) + OBCSAA compress/decode matmuls.
  prefill : 2·N·T + attention scores/AV.
  decode  : 2·N_active·B + per-layer cache attention.
Bytes:
  train   : params read fwd+bwd (bf16) + fp32 grad write + activation
            traffic ~ 16·B·S·d per layer + OBCSAA chunk/sign/BIHT traffic.
  prefill : params + 8·B·S·d per layer + KV cache write.
  decode  : active params + full KV/state cache read + logits.
"""
from __future__ import annotations

from repro.configs.base import InputShape, ModelConfig, TrainConfig


def _attn_layers(cfg: ModelConfig):
    """(n_global, n_local, window) attention-layer split."""
    if cfg.family == "ssm":
        return 0, 0, 0
    if cfg.family == "hybrid":
        n = cfg.num_layers // max(1, cfg.hybrid_attn_every)
        return n, 0, 0
    L = cfg.num_layers + cfg.num_encoder_layers
    a = cfg.attention
    if cfg.local_global_period:
        ng = cfg.num_layers // cfg.local_global_period
        return ng + cfg.num_encoder_layers, cfg.num_layers - ng, a.window
    if a and a.window:
        return cfg.num_encoder_layers, cfg.num_layers, a.window
    return L, 0, 0


def active_params(cfg: ModelConfig) -> int:
    n = cfg.param_count()
    if cfg.moe is None:
        return n
    m = cfg.moe
    n_mats = 3 if cfg.gated_mlp else 2
    per_expert = n_mats * cfg.d_model * cfg.d_ff
    return (n - cfg.num_layers * m.num_experts * per_expert
            + cfg.num_layers * m.top_k * per_expert)


def _attn_flops(cfg: ModelConfig, B: int, S: int, passes: float) -> float:
    ng, nl, window = _attn_layers(cfg)
    a = cfg.attention
    if a is None:
        return 0.0
    hd = cfg.head_dim if not a.use_mla else (a.qk_nope_dim + a.qk_rope_dim)
    H = a.num_heads
    f = 0.0
    f += ng * 4.0 * B * S * S * H * hd          # full layers: QK^T + AV
    if nl:
        w = min(window or S, S)
        f += nl * 4.0 * B * S * S * H * hd      # baseline computes full S^2
        # (masked; windowed-score skipping is a §Perf optimization)
    return f * passes


def obcsaa_flops(tcfg: TrainConfig, n_params: int) -> float:
    """Per-worker compress + PS decode (BIHT) matmuls over chunked Φ."""
    d = n_params
    compress = 2.0 * d * tcfg.cs_measure          # (D/Dc) chunks x 2·Sc·Dc
    decode = (4.0 * tcfg.biht_iters + 2.0) * d * tcfg.cs_measure
    return compress + decode


def flops_per_device(cfg: ModelConfig, shape: InputShape, n_dev: int,
                     agg: str = "obcsaa",
                     tcfg: TrainConfig = None) -> float:
    B, S = shape.global_batch, shape.seq_len
    na = active_params(cfg)
    tcfg = tcfg or TrainConfig()
    if shape.kind == "train":
        T = B * S
        f = 8.0 * na * T + _attn_flops(cfg, B, S, passes=4.0)
        if agg == "obcsaa":
            # compression per worker is sharded over model axis only; decode
            # sharded over all devices
            f += obcsaa_flops(tcfg, cfg.param_count())
        return f / n_dev
    if shape.kind == "prefill":
        T = B * S
        return (2.0 * na * T + _attn_flops(cfg, B, S, passes=1.0)) / n_dev
    # decode: one token, cache length S
    f = 2.0 * na * B
    ng, nl, window = _attn_layers(cfg)
    a = cfg.attention
    if a is not None:
        hd = cfg.head_dim if not a.use_mla else a.kv_lora_rank
        f += (ng * S + nl * min(window or S, S)) * 4.0 * B * a.num_heads * hd
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        f += cfg.num_layers * 6.0 * B * d_in * cfg.ssm.d_state
    return f / n_dev


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """KV/state cache size (bf16 entries, f32 SSM state)."""
    total = 0.0
    a = cfg.attention
    if cfg.family in ("dense", "vlm", "moe"):
        if a.use_mla:
            total += cfg.num_layers * B * S * (a.kv_lora_rank
                                               + a.qk_rope_dim) * 2
        else:
            total += cfg.num_layers * B * S * 2 * a.num_kv_heads \
                * cfg.head_dim * 2
    if cfg.family == "audio":
        total += cfg.num_layers * B * (S + cfg.encoder_seq_len) * 2 \
            * a.num_kv_heads * cfg.head_dim * 2
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        nheads = d_in // cfg.ssm.head_dim
        total += cfg.num_layers * B * nheads * cfg.ssm.head_dim \
            * cfg.ssm.d_state * 4
        total += cfg.num_layers * B * (cfg.ssm.conv_width - 1) \
            * (d_in + 2 * cfg.ssm.n_groups * cfg.ssm.d_state) * 2
    if cfg.family == "hybrid":
        total += cfg.num_layers * B * S * 2 * a.num_kv_heads \
            * cfg.head_dim * 2
    return total


def bytes_per_device(cfg: ModelConfig, shape: InputShape, n_dev: int,
                     agg: str = "obcsaa",
                     tcfg: TrainConfig = None) -> float:
    B, S = shape.global_batch, shape.seq_len
    n = cfg.param_count()
    na = active_params(cfg)
    d = cfg.d_model
    L = cfg.num_layers + cfg.num_encoder_layers
    tcfg = tcfg or TrainConfig()
    if shape.kind == "train":
        b = 2.0 * n * 2 + 4.0 * n          # params fwd+bwd bf16, grads f32
        b += L * 16.0 * B * S * d * 2      # activation traffic (remat'd)
        if agg == "obcsaa":
            D = cfg.param_count()
            iters = tcfg.biht_iters
            b += D * 4 * (2 + 2 * iters)   # chunk reads per BIHT pass
        return b / n_dev
    if shape.kind == "prefill":
        b = na * 2 + L * 8.0 * B * S * d * 2 + cache_bytes(cfg, B, S)
        return b / n_dev
    b = na * 2 + cache_bytes(cfg, B, S) + B * cfg.vocab_size * 4
    return b / n_dev
