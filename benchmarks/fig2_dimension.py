"""Paper Fig. 2: impact of the reduced dimension S (per-chunk S_c here).
Paper: S ∈ {1000..10000} at κ=1000; performance saturates with S and trades
off against communication (latency fraction S/D).

S is compile-static (Φ shapes): one engine build per S, seeds vmapped as
batched arms inside each build (DESIGN.md §11)."""
from __future__ import annotations

from benchmarks.common import acc_summary, emit, run_fl_sweep
from repro.core import comm_stats
from repro.core.obcsaa import OBCSAAConfig

MEASURES = [256, 512, 1024, 2048]
ROUNDS = 120
SEEDS = (0, 1, 2)


def main(rounds=ROUNDS):
    rows = []
    for s in MEASURES:
        ob = OBCSAAConfig(chunk=4096, measure=s, topk=80, biht_iters=25)
        r = run_fl_sweep("obcsaa", rounds=rounds, obcsaa=ob, seeds=SEEDS)
        st = comm_stats(ob, 50890)
        rows.append((f"fig2/obcsaa_S{s}x13", r["us_per_round"],
                     f"{acc_summary(r)};"
                     f"latency_frac={st['latency_fraction']:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
