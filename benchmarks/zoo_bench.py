"""Sharded model-zoo FL round throughput (engine/zoo.py, DESIGN.md §14).

How fast does one full OBCSAA round (surrogate grads → 1-bit compress →
packed int32 MAC → AWGN → chunked decode → update) run when the parameter
vector is partitioned over the whole 8-device mesh and NOTHING dense at
full D is ever replicated? Measured as rounds/sec on the host mesh
(4 FL workers × 2 model shards), per architecture.

Methodology:

- Every measurement runs in a CHILD process so the 8-device XLA host flag
  never leaks into the caller (the bench harness keeps 1 device).
- Default rows are CI-scale: a parity gate (the 16k-element geometry of
  tests/test_zoo.py — the sharded round must stay BITWISE equal to the
  single-device reference over a 2-round chain) plus smoke-config rounds
  for two architectures. CI asserts the deterministic parity flag, never
  a timing ratio (the PR-3 convention).
- ``--full`` regenerates the zoo-scale row: the gemma2-2b FULL config
  (2.614B parameters — the ≥1B acceptance config) with the wide-chunk
  geometry D_c=16384, S_c=32, κ_c=8. Parameters stay 8-way sharded
  (1.3 GB/device); each worker column gathers one model-half and
  compresses it in 64-chunk ``lax.map`` blocks, so peak memory is bounded
  by the half + decode workspace, not U×D. The measured row is cached in
  experiments/bench_cache.json and replayed by default runs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import CACHE_PATH, cached_rows, emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FULL_KEY = "zoo:v1:full"

CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json, sys, time
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine.zoo import build_zoo_round
    from repro.launch.mesh import make_zoo_mesh

    spec = json.loads(sys.argv[1])
    mesh = make_zoo_mesh(spec["workers"], spec["mp"])
    if spec.get("arch"):
        from repro.configs import get_config, get_smoke_config
        from repro.models.registry import build_model
        cfg = (get_smoke_config if spec["smoke"] else get_config)(
            spec["arch"])
        shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        D = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(shapes))
    else:
        D = spec["D"]
    ob = OBCSAAConfig(**spec["ob"])
    zr = build_zoo_round(ob, D, mesh)
    params = jax.jit(
        lambda: jnp.zeros((zr.n_chunks, ob.chunk), jnp.float32),
        out_shardings=NamedSharding(mesh, zr.spec))()

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    params, st = zr.round_gen(params, 0, key, 1e-4, 10.0, 0.05)
    jax.block_until_ready(params)
    compile_s = time.time() - t0
    t0 = time.time()
    for t in range(1, 1 + spec["rounds"]):
        params, st = zr.round_gen(params, t, key, 1e-4, 10.0, 0.05)
    jax.block_until_ready(params)
    wall = time.time() - t0

    out = {"D": D, "D_pad": zr.D_pad, "n_chunks": zr.n_chunks,
           "workers": zr.U, "mp": zr.n_model, "rounds": spec["rounds"],
           "compile_s": compile_s, "wall_s": wall,
           "ghat_norm": float(st.ghat_norm),
           "finite": bool(np.isfinite(float(st.ghat_norm)))}
    if spec.get("parity"):
        rc = zr.chunk_params(jnp.zeros((D,), jnp.float32))
        for t in range(1 + spec["rounds"]):
            rc, _ = zr.reference_round(rc, t, key, 1e-4, 10.0, 0.05)
        out["parity"] = bool(np.array_equal(np.asarray(params),
                                            np.asarray(rc)))
    print("ZOO_RESULT " + json.dumps(out))
""")


def _child(spec: dict, timeout: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", CHILD, json.dumps(spec)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"zoo child failed:\n{r.stderr[-3000:]}")
    line = [l for l in r.stdout.splitlines()
            if l.startswith("ZOO_RESULT ")][-1]
    return json.loads(line[len("ZOO_RESULT "):])


def _row(name: str, res: dict) -> tuple:
    us = 1e6 * res["wall_s"] / max(res["rounds"], 1)
    rate = res["rounds"] / res["wall_s"] if res["wall_s"] > 0 else 0.0
    derived = (f"D={res['D']};mesh={res['workers']}x{res['mp']};"
               f"rounds_per_s={rate:.4g};compile_s={res['compile_s']:.1f};"
               f"finite={res['finite']}")
    if "parity" in res:
        derived += f";parity={res['parity']}"
    return (name, us, derived)


SMOKE_OB = dict(chunk=1024, measure=128, topk=32, biht_iters=3,
                recon_alg="iht", spmd_topk=True, packed=True,
                bisect_iters=16)
PARITY_OB = dict(chunk=256, measure=64, topk=16, biht_iters=3,
                 recon_alg="iht", spmd_topk=True, packed=True,
                 bisect_iters=16)
# ≥1B geometry: wide chunks keep n_chunks (and the decode batch) bounded;
# S_c=32 is one packed uint32 word per chunk on the wire
FULL_OB = dict(chunk=16384, measure=32, topk=8, biht_iters=2,
               recon_alg="iht", spmd_topk=True, packed=True,
               bisect_iters=10)


def _smoke_rows():
    rows = [_row("zoo/parity-16k", _child(
        {"D": 16000, "ob": PARITY_OB, "rounds": 2, "workers": 4, "mp": 2,
         "parity": True}, timeout=600))]
    for arch in ("gemma2-2b", "mamba2-2.7b"):
        rows.append(_row(f"zoo/{arch}-smoke", _child(
            {"arch": arch, "smoke": True, "ob": SMOKE_OB, "rounds": 3,
             "workers": 4, "mp": 2}, timeout=600)))
    return rows


def _full_rows():
    res = _child({"arch": "gemma2-2b", "smoke": False, "ob": FULL_OB,
                  "rounds": 1, "workers": 4, "mp": 2}, timeout=14400)
    assert res["D"] >= 1_000_000_000, res
    return [_row("zoo/gemma2-2b-2.6B", res)]


def _store(key: str, rows):
    cache = json.loads(CACHE_PATH.read_text()) if CACHE_PATH.exists() else {}
    cache[key] = [list(r) for r in rows]
    CACHE_PATH.parent.mkdir(parents=True, exist_ok=True)
    CACHE_PATH.write_text(json.dumps(cache, indent=1))


def main(full: bool = False):
    """CI-scale rows run FRESH every time (they carry the parity gate);
    the ≥1B row replays from experiments/bench_cache.json unless --full
    regenerates it."""
    rows = _smoke_rows()
    _store("zoo:v1", rows)        # make_experiments_md reads the cache
    emit(rows)
    if full:
        frows = _full_rows()
        _store(FULL_KEY, frows)
        emit(frows)
    else:
        frows = cached_rows(FULL_KEY)
        if frows:
            emit(frows)
    return rows + (frows or [])


if __name__ == "__main__":
    main(full="--full" in sys.argv)
