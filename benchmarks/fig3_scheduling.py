"""Paper Fig. 3: enumeration vs ADMM joint optimization under different U.
Also times the solvers (O(2^U) vs O(U)) — the paper's complexity claim.

The enum/admm FL rows run the host reference loop (enum is not
jittable); the ``fl_admm_batched`` row is the same workload on the scan
engine with Algorithm 2 inlined per round and seeds as batched arms
(DESIGN.md §11)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import acc_summary, emit, run_fl, run_fl_sweep
from repro.theory import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig
from repro.sched import Problem, admm_solve, enumerate_solve

ROUNDS = 100


def solver_timing():
    rows = []
    rng = np.random.default_rng(0)
    for U in (6, 10, 14):
        prob = Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                       k_weights=np.full(U, 3000.0), p_max=10.0,
                       noise_var=1e-4, D=50890, S=1000, kappa=1000,
                       const=AnalysisConstants(rho1=200.0, G=1.0))
        t0 = time.time()
        _, _, r_enum = enumerate_solve(prob)
        t_enum = time.time() - t0
        t0 = time.time()
        _, _, r_admm = admm_solve(prob)
        t_admm = time.time() - t0
        rows.append((f"fig3/solver_enum_U{U}", t_enum * 1e6,
                     f"Rt={r_enum:.4f}"))
        rows.append((f"fig3/solver_admm_U{U}", t_admm * 1e6,
                     f"Rt={r_admm:.4f};gap={(r_admm/r_enum-1)*100:.2f}%"))
    # ADMM-only scaling (enumeration infeasible, paper Remark 2)
    for U in (64, 256):
        prob = Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                       k_weights=np.full(U, 3000.0), p_max=10.0,
                       noise_var=1e-4, D=50890, S=1000, kappa=1000,
                       const=AnalysisConstants(rho1=200.0, G=1.0))
        t0 = time.time()
        admm_solve(prob)
        rows.append((f"fig3/solver_admm_U{U}", (time.time() - t0) * 1e6, ""))
    return rows


def main(rounds=ROUNDS):
    rows = solver_timing()
    for U, sched in [(6, "enum"), (6, "admm"), (10, "enum"), (10, "admm")]:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
        r = run_fl("obcsaa", rounds=rounds, U=U, K=1000, scheduler=sched,
                   obcsaa=ob)
        rows.append((f"fig3/fl_{sched}_U{U}", r["us_per_round"],
                     f"acc={r['final_acc']:.4f};loss={r['final_loss']:.4f}"))
    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
    r = run_fl_sweep("obcsaa", rounds=rounds, U=10, K=1000,
                     scheduler="admm_batched", obcsaa=ob, seeds=(0, 1, 2))
    rows.append(("fig3/fl_admm_batched_U10", r["us_per_round"],
                 acc_summary(r)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
