"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run              # quick set
  PYTHONPATH=src python -m benchmarks.run --full       # paper-scale rounds
  PYTHONPATH=src python -m benchmarks.run --only fig1,roofline
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale round counts (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig1..fig5,kernels,"
                         "decoders,sched,engine,theory,ablations,roofline,"
                         "zoo,serve")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None
    rounds = 300 if args.full else 60

    from benchmarks import (ablations, decoders_bench, engine_bench,
                            fig1_sparsification, fig2_dimension,
                            fig3_scheduling, fig4_samples, fig5_noise,
                            kernels_bench, roofline, sched_bench,
                            serve_bench, theory_bench, zoo_bench)

    from benchmarks.common import cached_suite

    suites = {
        "fig1": lambda: fig1_sparsification.main(rounds=rounds),
        "fig2": lambda: fig2_dimension.main(rounds=rounds),
        "fig3": lambda: fig3_scheduling.main(rounds=max(40, rounds // 2)),
        "fig4": lambda: fig4_samples.main(rounds=max(40, rounds // 2)),
        "fig5": lambda: fig5_noise.main(rounds=max(40, rounds // 2)),
        "kernels": kernels_bench.main,
        "decoders": decoders_bench.main,
        "sched": sched_bench.main,
        "engine": engine_bench.main,
        "theory": theory_bench.main,
        "ablations": lambda: ablations.main(rounds=max(40, rounds // 2)),
        "roofline": roofline.main,   # cheap, always fresh (reads dryrun/)
        "zoo": lambda: zoo_bench.main(full=args.full),
        "serve": lambda: serve_bench.main(full=args.full),
    }
    # kernels + sched + engine + theory + roofline + zoo + serve always
    # run fresh: they are the CI smoke steps and must exercise real code,
    # not replay experiments/bench_cache.json (zoo and serve manage their
    # own expensive cached rows — ≥1B zoo, 1M-cell serve — while their
    # CI-scale rows, including every parity gate, run live)
    fresh = {"kernels", "sched", "engine", "theory", "roofline", "zoo",
             "serve"}
    # fig/ablation suites moved to engine arms sweeps (v2): the v1 cache
    # rows were produced by the pre-engine loop AND its half-normal
    # channel draw — keys are bumped so a full run regenerates them
    vkey = {"fig1": 2, "fig2": 2, "fig3": 2, "fig4": 2, "fig5": 2,
            "ablations": 2}
    print("name,us_per_call,derived", flush=True)
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            if name in fresh:
                fn()
            else:
                key = f"{name}:v{vkey[name]}:r{rounds}" if name in vkey \
                    else f"{name}:r{rounds}"
                cached_suite(key, fn)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout, flush=True)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
