"""Theory validation: predicted Theorem-1 bound vs measured trajectory,
and bound-driven design tuning (repro.theory, DESIGN.md §12).

The companion-paper methodology (arXiv:2104.03490, arXiv:2310.10089):
select design parameters from the closed-form convergence bound, then
validate the prediction against a measured training run. Two questions:

1. **Does the bound hold?** ONE ``run_sweep`` call advances ≥2 SNR arms
   of the MNIST-MLP task with the measured-aggregation-error probe on;
   the per-round predicted R_t (eq. 24, emitted in-scan as the
   ``ErrorBudget`` outputs) must dominate the measured ‖ĝ−ḡ‖² at EVERY
   logged round of EVERY arm. The analysis constant G is instantiated
   from the actual initial worker gradients (×``G_MARGIN``) instead of
   the paper's abstract G — the same instantiated-constants convention as
   tests/test_obcsaa.py — so the bound is non-vacuous.
2. **Does tuning on the bound transfer?** ``tune_design`` sweeps the
   (κ_c, S_c) grid under the paper's per-round uplink symbol budget and
   its chosen design runs against a mistuned baseline at the SAME symbol
   cost (κ_c far beyond the RIP-feasible sparsity, the configuration the
   δ-model flags as C(δ) → ∞). The win is judged on the bound's own
   prediction target — measured aggregation error — with final loss/acc
   reported alongside: eq. (19)'s worst-case sparsification term is
   nearly flat in κ at MLP scale, so bound-optimal designs sparsify
   aggressively; the actionable tuner signal is the RIP-feasibility cut
   (documented in DESIGN.md §12 and the EXPERIMENTS.md table note).

CI asserts the deterministic flags (`bound_ge_measured`,
`tuned_beats_mistuned`), not wall-clock (the §10/§11 convention).
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import mnist_setup
from repro.core.obcsaa import OBCSAAConfig
from repro.engine import FLConfig, run_sweep
from repro.engine.core import stacked_grads
from repro.theory import AnalysisConstants, tune_design

U, K = 10, 3000                    # paper §V fleet
ROUNDS = 24
NOISE_ARMS = [1e-4, 1e-2]          # the ≥2 SNR arms of the acceptance gate
D_CHUNK, S_C, KAPPA = 4096, 1024, 80   # paper-scale operating point
G_MARGIN = 2.0
MISTUNED_KAPPA = 2048              # κ_c > S_c: RIP-infeasible at S_c=1024
TUNE_KAPPAS = [20, 40, 80, 160, 320, 640, 1280, MISTUNED_KAPPA]
TUNE_MEASURES = [128, 256, 512, 1024]


def _const(loss_fn, params0, worker_data) -> AnalysisConstants:
    """Analysis constants instantiated from the task: G from the actual
    initial per-worker gradient norms (eq. 18) with a safety margin."""
    g = stacked_grads(loss_fn, params0, worker_data)
    g_max = float(np.max(np.linalg.norm(np.asarray(g), axis=-1)))
    return AnalysisConstants(G=G_MARGIN * g_max)


def _cfg(const, kappa=KAPPA, measure=S_C, probe=True) -> FLConfig:
    return FLConfig(
        aggregator="obcsaa", scheduler="greedy_batched", rounds=ROUNDS,
        obcsaa=OBCSAAConfig(chunk=D_CHUNK, measure=measure, topk=kappa,
                            biht_iters=10, recon_alg="iht",
                            recon_tau=0.25),
        const=const, probe_agg_error=probe)


def _sweep(cfg, loss_fn, params0, worker_data, eval_fn):
    t0 = time.time()
    out = run_sweep(cfg, loss_fn, params0, worker_data,
                    np.full(U, float(K)), eval_fn=eval_fn, rounds=ROUNDS,
                    eval_every=ROUNDS, noise_var=NOISE_ARMS)
    jax.block_until_ready(out["state"].params)
    out["wall_s"] = time.time() - t0
    return out


def main() -> List[tuple]:
    worker_data, params0, eval_fn, loss_fn = mnist_setup(U=U, K=K)
    const = _const(loss_fn, params0, worker_data)
    rows = []

    # -- 1. predicted bound vs measured error, one sweep, 2 SNR arms ------
    out = _sweep(_cfg(const), loss_fn, params0, worker_data, eval_fn)
    n = len(NOISE_ARMS) * ROUNDS
    for a, nv in enumerate(NOISE_ARMS):
        bound, meas = out["rt_bound"][a], out["agg_err"][a]
        rows.append((
            f"theory/bound_vs_measured_snr{nv:g}",
            out["wall_s"] / n * 1e6,
            f"bound_ge_measured={bool(np.all(bound >= meas))};"
            f"rounds={ROUNDS};min_bound={bound.min():.1f};"
            f"max_measured={meas.max():.3f};"
            f"median_gap={np.median(bound / meas):.0f}x"))

    # -- 2. bound-driven tuning under the paper's symbol budget -----------
    D = sum(int(np.prod(np.asarray(l).shape))
            for l in jax.tree_util.tree_leaves(params0))
    n_chunks = -(-D // D_CHUNK)
    b_nom = float(np.median(out["b_t"]))
    tuned = tune_design(const, D=D, d_chunk=D_CHUNK, kappas=TUNE_KAPPAS,
                        measures=TUNE_MEASURES, decode_iters=[10],
                        k_weights=np.full(U, float(K)),
                        noise_var=max(NOISE_ARMS), b_t=b_nom,
                        max_symbols=n_chunks * (S_C + 1))
    k_star = int(tuned["kappa"][tuned["best"]])
    s_star = int(tuned["measure"][tuned["best"]])
    n_feas = int(np.sum(np.isfinite(tuned["rt"])
                        & (tuned["symbols"] <= n_chunks * (S_C + 1))))
    rows.append((
        "theory/tuner_grid", 0.0,
        f"candidates={len(tuned['rt'])};pareto={int(tuned['pareto'].sum())};"
        f"feasible_in_budget={n_feas};"
        f"chosen_kappa={k_star};chosen_S={s_star};"
        f"calib={tuned['calib']:.3f}"))

    # -- 3. empirical cross-check: tuned vs mistuned at equal symbols -----
    res = {}
    for tag, kappa, measure in (("tuned", k_star, s_star),
                                ("mistuned", MISTUNED_KAPPA, S_C)):
        o = _sweep(_cfg(const, kappa=kappa, measure=measure), loss_fn,
                   params0, worker_data, eval_fn)
        res[tag] = o
        rows.append((
            f"theory/empirical_{tag}_k{kappa}_S{measure}",
            o["wall_s"] / n * 1e6,
            f"mean_agg_err={o['agg_err'].mean():.3f};"
            f"final_loss={o['loss'][:, -1].mean():.4f};"
            f"final_acc={o['accuracy'][:, -1].mean():.4f}"))
    beats = (res["tuned"]["agg_err"].mean()
             < res["mistuned"]["agg_err"].mean())
    # the budget-equality control is computed, not asserted by fiat: both
    # arms must spend the same per-round uplink symbols (DESIGN.md §4)
    eq_budget = n_chunks * (s_star + 1) == n_chunks * (S_C + 1)
    # prediction consistency: the closed form ranks the designs the same
    # way the measured errors do (mistuned is RIP-infeasible ⇒ R_t = ∞)
    pred_order = not np.isfinite(
        float(tuned["rt"][np.argmax(
            (tuned["kappa"] == MISTUNED_KAPPA)
            & (tuned["measure"] == S_C))])) \
        if MISTUNED_KAPPA in tuned["kappa"] else True
    rows.append((
        "theory/tuned_vs_mistuned", 0.0,
        f"tuned_beats_mistuned={bool(beats)};metric=mean_agg_err;"
        f"equal_symbol_budget={bool(eq_budget)};"
        f"err_ratio={res['mistuned']['agg_err'].mean() / res['tuned']['agg_err'].mean():.2f}x;"
        f"predicted_order_matches_measured={bool(pred_order)}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    main()
