"""Paper Fig. 1: OBCSAA under different sparsification levels κ vs perfect
aggregation. Sweeps per-chunk κ_c at fixed S_c (paper: κ ∈ {10..1000},
S=10000, D=50890; here the equivalent per-chunk budgets).

κ is compile-static (top-κ selection shapes), so each κ builds one
engine; WITHIN each build the seeds axis runs as vmapped batched arms in
a single scan×vmap program (DESIGN.md §11)."""
from __future__ import annotations

from benchmarks.common import acc_summary, emit, run_fl_sweep
from repro.core.obcsaa import OBCSAAConfig

# per-chunk κ_c equivalents of the paper's κ over D=50890 with 13 chunks
KAPPAS = [8, 26, 80, 160]       # ≈ paper κ = 100, 330, 1000, 2000
ROUNDS = 120
SEEDS = (0, 1, 2)


def main(rounds=ROUNDS):
    rows = []
    r = run_fl_sweep("perfect", rounds=rounds, seeds=SEEDS)
    rows.append(("fig1/perfect", r["us_per_round"], acc_summary(r)))
    for k in KAPPAS:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=k, biht_iters=25)
        r = run_fl_sweep("obcsaa", rounds=rounds, obcsaa=ob, seeds=SEEDS)
        rows.append((f"fig1/obcsaa_kappa{k}x13", r["us_per_round"],
                     acc_summary(r)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
