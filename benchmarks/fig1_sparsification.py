"""Paper Fig. 1: OBCSAA under different sparsification levels κ vs perfect
aggregation. Sweeps per-chunk κ_c at fixed S_c (paper: κ ∈ {10..1000},
S=10000, D=50890; here the equivalent per-chunk budgets)."""
from __future__ import annotations

from benchmarks.common import emit, run_fl
from repro.core.obcsaa import OBCSAAConfig

# per-chunk κ_c equivalents of the paper's κ over D=50890 with 13 chunks
KAPPAS = [8, 26, 80, 160]       # ≈ paper κ = 100, 330, 1000, 2000
ROUNDS = 120


def main(rounds=ROUNDS):
    rows = []
    r = run_fl("perfect", rounds=rounds)
    rows.append(("fig1/perfect", r["us_per_round"],
                 f"acc={r['final_acc']:.4f};loss={r['final_loss']:.4f}"))
    for k in KAPPAS:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=k, biht_iters=25)
        r = run_fl("obcsaa", rounds=rounds, obcsaa=ob)
        rows.append((f"fig1/obcsaa_kappa{k}x13", r["us_per_round"],
                     f"acc={r['final_acc']:.4f};loss={r['final_loss']:.4f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
