"""Paper Fig. 4: impact of samples-per-worker K̄ (performance saturates)."""
from __future__ import annotations

from benchmarks.common import emit, run_fl
from repro.core.obcsaa import OBCSAAConfig

KBARS = [300, 1000, 3000]
ROUNDS = 100


def main(rounds=ROUNDS):
    rows = []
    for K in KBARS:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
        r = run_fl("obcsaa", rounds=rounds, K=K, obcsaa=ob)
        rows.append((f"fig4/obcsaa_K{K}", r["us_per_round"],
                     f"acc={r['final_acc']:.4f};loss={r['final_loss']:.4f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
