"""Paper Fig. 4: impact of samples-per-worker K̄ (performance saturates).

K̄ fixes the worker-data shapes (compile-static): one engine build per K̄,
seeds vmapped as batched arms inside each build (DESIGN.md §11)."""
from __future__ import annotations

from benchmarks.common import acc_summary, emit, run_fl_sweep
from repro.core.obcsaa import OBCSAAConfig

KBARS = [300, 1000, 3000]
ROUNDS = 100
SEEDS = (0, 1, 2)


def main(rounds=ROUNDS):
    rows = []
    for K in KBARS:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
        r = run_fl_sweep("obcsaa", rounds=rounds, K=K, obcsaa=ob,
                         seeds=SEEDS)
        rows.append((f"fig4/obcsaa_K{K}", r["us_per_round"],
                     acc_summary(r)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
