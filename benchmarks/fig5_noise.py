"""Paper Fig. 5: impact of AWGN variance σ² (SNR sweep)."""
from __future__ import annotations

from benchmarks.common import emit, run_fl
from repro.core.obcsaa import OBCSAAConfig

NOISE_VARS = [1e-6, 1e-4, 1e-2, 1.0]
ROUNDS = 100


def main(rounds=ROUNDS):
    rows = []
    for nv in NOISE_VARS:
        ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25,
                          noise_var=nv)
        r = run_fl("obcsaa", rounds=rounds, obcsaa=ob)
        snr_db = 10 * __import__("math").log10(10.0 / nv)
        rows.append((f"fig5/obcsaa_noise{nv:g}", r["us_per_round"],
                     f"acc={r['final_acc']:.4f};loss={r['final_loss']:.4f};"
                     f"snr={snr_db:.0f}dB"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
