"""Paper Fig. 5: impact of AWGN variance σ² (SNR sweep).

σ² is a DYNAMIC engine arm axis (Arms.noise_var): the whole SNR grid —
noise levels × seeds — runs as ONE scan×vmap program instead of a
fig-script loop (DESIGN.md §11)."""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import emit, run_fl_sweep
from repro.core.obcsaa import OBCSAAConfig

NOISE_VARS = [1e-6, 1e-4, 1e-2, 1.0]
ROUNDS = 100
SEEDS = (0, 1, 2)


def main(rounds=ROUNDS):
    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
    # full grid in one engine call: arms = noise levels × seeds
    noise = [nv for nv in NOISE_VARS for _ in SEEDS]
    seeds = list(SEEDS) * len(NOISE_VARS)
    r = run_fl_sweep("obcsaa", rounds=rounds, obcsaa=ob, seeds=seeds,
                     noise_var=noise)
    acc = r["final_acc"].reshape(len(NOISE_VARS), len(SEEDS))
    loss = r["final_loss"].reshape(len(NOISE_VARS), len(SEEDS))
    rows = []
    for i, nv in enumerate(NOISE_VARS):
        snr_db = 10 * math.log10(10.0 / nv)
        rows.append((f"fig5/obcsaa_noise{nv:g}", r["us_per_round"],
                     f"acc={np.mean(acc[i]):.4f};loss={np.mean(loss[i]):.4f};"
                     f"arms={len(SEEDS)};snr={snr_db:.0f}dB"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
