"""Continuous scheduling-service SLO benchmark (repro.serve, DESIGN.md §15).

What does serving P2 schedules to a fleet cost at steady state? Each SLO
row drives the serve loop — fade step → CSI reports → dirty set → pow2
compaction → batched solve → cache — for a fixed number of timed ticks
after an untimed warm-up (compilation + cache fill), and reports p50/p99
tick latency, the cache-hit rate, and throughput both as schedules
actually solved per second and as cells served per second.

Methodology (the PR-3 convention: CI gates deterministic flags, never
timing ratios):

- ``serve/cache-parity`` runs the service at ``stale_threshold=0`` with
  partial CSI reporting, then checks the served cache against a cold
  full-fleet ``fresh_solve`` — bitwise over (β, b_t, R_t), for both
  solvers. This is the flag that proves caching never changes results:
  at threshold 0 a cell re-solves on ANY channel movement, so cache
  hits are exactly the cells whose channels did not change.
- ``serve/warm-parity`` solves a held-out batch cold and dual-warm-
  started (multipliers seeded from a correlated earlier batch, the
  serve-loop usage) and asserts bitwise-equal β at the compaction exit;
  the cold/warm mean outer-iteration counts ride along as telemetry.
  The row also measures the opt-in ``warm_beta`` primal seed (cached β
  projected feasible, sched/admm.py) as telemetry only —
  ``primal_warm_iters`` / ``primal_warm_parity`` — because a primal
  seed moves the ADMM trajectory: measured, it saves no outer
  iterations over dual-only, which is why it earns no default.
- SLO rows at 10k and 100k cells run fresh every time; the 1M-cell row
  (~minutes of wall clock) is cached in experiments/bench_cache.json
  and replayed by default runs — ``--full`` regenerates it (the zoo
  convention).
"""
from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE_PATH, cached_rows, emit

FULL_KEY = "serve:v1:full"

# Steady-state fleet policy for the SLO rows: slow fading (ρ = 0.999 ≈
# 4.5% innovation/tick), half the fleet reporting CSI each tick, re-solve
# past 5% worst-worker movement — a regime where the cache does real work
_CORR = 0.999
_THRESHOLD = 0.05
_UPDATE_FRAC = 0.5
_WORKERS = 16


def _store(key: str, rows):
    cache = json.loads(CACHE_PATH.read_text()) if CACHE_PATH.exists() else {}
    cache[key] = [list(r) for r in rows]
    CACHE_PATH.parent.mkdir(parents=True, exist_ok=True)
    CACHE_PATH.write_text(json.dumps(cache, indent=1))


def _slo_row(name: str, cells: int, scheduler: str, ticks: int,
             warmup: int, seed: int = 0) -> tuple:
    from repro.sched.scenario import ScenarioConfig
    from repro.serve import ServeConfig, init_service, run_ticks, slo_summary

    cfg = ServeConfig(
        scenario=ScenarioConfig(cells=cells, workers=_WORKERS, corr=_CORR),
        scheduler=scheduler, stale_threshold=_THRESHOLD,
        update_frac=_UPDATE_FRAC)
    state = init_service(cfg, jax.random.PRNGKey(seed))
    state, _, _ = run_ticks(cfg, state, warmup)      # compile + fill cache
    state, stats, lat = run_ticks(cfg, state, ticks, timed=True)
    slo = slo_summary(stats, lat, cells)
    derived = (f"cells={cells};sched={scheduler};"
               f"p50_ms={slo['p50_ms']:.2f};p99_ms={slo['p99_ms']:.2f};"
               f"hit_rate={slo['hit_rate']:.3f};"
               f"solved_per_s={slo['solved_per_s']:.0f};"
               f"served_per_s={slo['served_per_s']:.0f};ticks={ticks}")
    return (name, slo["mean_ms"] * 1e3, derived)


def _cache_parity_row(cells: int = 384, ticks: int = 6) -> tuple:
    """threshold-0 cache ≡ fresh full-fleet solve, bitwise, both solvers."""
    from repro.sched.scenario import ScenarioConfig
    from repro.serve import ServeConfig, fresh_solve, init_service, run_ticks

    flags, hits = [], []
    for scheduler in ("admm_batched", "greedy_batched"):
        cfg = ServeConfig(
            scenario=ScenarioConfig(cells=cells, workers=_WORKERS,
                                    corr=_CORR),
            scheduler=scheduler, stale_threshold=0.0, update_frac=0.35)
        state = init_service(cfg, jax.random.PRNGKey(1))
        state, stats, _ = run_ticks(cfg, state, ticks)
        beta, b_t, rt = fresh_solve(cfg, state)
        flags.append(np.array_equal(np.asarray(beta), np.asarray(state.beta))
                     and np.array_equal(np.asarray(b_t),
                                        np.asarray(state.b_t))
                     and np.array_equal(np.asarray(rt),
                                        np.asarray(state.rt)))
        hits.append(np.mean([s.hit_rate for s in stats[1:]]))
    derived = (f"cache_parity={all(flags)};cells={cells};ticks={ticks};"
               f"admm_hit_rate={hits[0]:.3f};greedy_hit_rate={hits[1]:.3f}")
    return ("serve/cache-parity", 0.0, derived)


def _warm_parity_row(B: int = 256, U: int = _WORKERS) -> tuple:
    """Dual-warm-started ADMM ≡ cold-start β, bitwise, on a held-out
    batch whose warm duals come from a correlated earlier batch."""
    from repro.sched.admm import admm_solve_batched
    from repro.sched.problem import BatchedProblem
    from repro.theory.bounds import AnalysisConstants
    from repro.core.channel import draw_cn, gauss_markov_step

    const = AnalysisConstants(rho1=200.0, G=1.0)

    def problem(g):
        h = jnp.maximum(jnp.abs(g).astype(jnp.float32), 1e-3)
        return BatchedProblem.from_arrays(h, 3000.0, 10.0, 1e-4, D=50890,
                                          S=1000, kappa=1000, const=const)

    k0, k1 = jax.random.split(jax.random.PRNGKey(2))
    g0 = draw_cn(k0, (B, U))
    beta0, _, _, info0 = admm_solve_batched(problem(g0), return_duals=True)
    g1 = gauss_markov_step(g0, k1, _CORR)       # held-out correlated batch
    prob1 = problem(g1)
    beta_c, _, _, ic = admm_solve_batched(prob1, return_duals=True)
    beta_w, _, _, iw = admm_solve_batched(prob1, duals=info0.duals,
                                          return_duals=True)
    # primal+dual warm start (cached-β projection, sched/admm.py): honest
    # telemetry only — it moves the ADMM trajectory, so β parity is
    # reported, not gated, and iteration counts decide whether it earns
    # a default (it doesn't: no win over dual-only on correlated fades)
    beta_p, _, _, ip = admm_solve_batched(prob1, duals=info0.duals,
                                          warm_beta=beta0,
                                          return_duals=True)
    flag = np.array_equal(np.asarray(beta_c), np.asarray(beta_w))
    pflag = np.array_equal(np.asarray(beta_c), np.asarray(beta_p))
    derived = (f"warm_parity={flag};B={B};U={U};"
               f"cold_iters={float(ic.iters.mean()):.2f};"
               f"warm_iters={float(iw.iters.mean()):.2f};"
               f"primal_warm_iters={float(ip.iters.mean()):.2f};"
               f"primal_warm_parity={pflag}")
    return ("serve/warm-parity", 0.0, derived)


def _smoke_rows():
    return [
        _cache_parity_row(),
        _warm_parity_row(),
        _slo_row("serve/slo-10k-admm", 10_000, "admm_batched",
                 ticks=8, warmup=2),
        _slo_row("serve/slo-100k-greedy", 100_000, "greedy_batched",
                 ticks=8, warmup=2),
    ]


def _full_rows():
    return [_slo_row("serve/slo-1M-greedy", 1_000_000, "greedy_batched",
                     ticks=5, warmup=1)]


def main(full: bool = False):
    """Parity flags + 10k/100k SLO rows run FRESH every time (they are
    the CI gate); the 1M-cell row replays from
    experiments/bench_cache.json unless --full regenerates it."""
    rows = _smoke_rows()
    _store("serve:v1", rows)      # make_experiments_md reads the cache
    emit(rows)
    if full:
        frows = _full_rows()
        _store(FULL_KEY, frows)
        emit(frows)
    else:
        frows = cached_rows(FULL_KEY)
        if frows:
            emit(frows)
    return rows + (frows or [])


if __name__ == "__main__":
    main(full="--full" in sys.argv)
