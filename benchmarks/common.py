"""Shared FL-experiment harness for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.obcsaa import OBCSAAConfig
from repro.data import load_mnist, partition_workers
from repro.fl import FederatedTrainer, FLConfig
from repro.models.mlp_mnist import (init_mlp_mnist, mlp_mnist_accuracy,
                                    mlp_mnist_loss)

_CACHE = {}


def mnist_setup(U=10, K=3000, seed=0, n_eval=2000, iid=True):
    key = (U, K, seed, n_eval, iid)
    if key in _CACHE:
        return _CACHE[key]
    xtr, ytr, xte, yte = load_mnist()
    wx, wy = partition_workers(xtr, ytr, U, K, seed=seed, iid=iid)
    worker_data = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    params0 = init_mlp_mnist(jax.random.PRNGKey(0))
    xe, ye = jnp.asarray(xte[:n_eval]), jnp.asarray(yte[:n_eval])

    @jax.jit
    def eval_fn(p):
        return mlp_mnist_loss(p, xe, ye), mlp_mnist_accuracy(p, xe, ye)

    def loss_fn(p, data):
        return mlp_mnist_loss(p, data["x"], data["y"])

    out = (worker_data, params0, eval_fn, loss_fn)
    _CACHE[key] = out
    return out


def run_fl(agg: str, *, rounds=120, U=10, K=3000, scheduler="all",
           obcsaa: OBCSAAConfig = None, topk_dense=1000, eval_every=20,
           seed=0) -> Dict:
    worker_data, params0, eval_fn, loss_fn = mnist_setup(U=U, K=K)
    cfg = FLConfig(aggregator=agg, scheduler=scheduler, rounds=rounds,
                   eval_every=eval_every, seed=seed,
                   obcsaa=obcsaa or OBCSAAConfig(chunk=4096, measure=1024,
                                                 topk=80, biht_iters=25),
                   topk_dense=topk_dense)
    tr = FederatedTrainer(cfg, loss_fn, params0, worker_data,
                          np.full(U, float(K)), eval_fn=eval_fn)
    t0 = time.time()
    logs = tr.run()
    wall = time.time() - t0
    return {"logs": logs, "wall_s": wall,
            "final_loss": logs[-1].loss, "final_acc": logs[-1].accuracy,
            "us_per_round": 1e6 * wall / rounds}


def run_fl_sweep(agg: str, *, rounds=120, U=10, K=3000, scheduler="all",
                 obcsaa: OBCSAAConfig = None, topk_dense=1000,
                 eval_every=20, seeds=(0,), noise_var=None, p_max=None,
                 lr=None, error_feedback=False, iid=True) -> Dict:
    """Engine-backed arms sweep (DESIGN.md §11): every (seed × σ² × P^Max
    × α) combination advances as ONE scan×vmap program — the batched
    replacement for looping ``run_fl`` per fig-script arm. Static knobs
    (κ, S, aggregator, scheduler) stay per-call; pass sequences for the
    dynamic axes. Returns the engine sweep dict plus per-arm finals and
    the per-arm-round wall clock."""
    from repro.engine import run_sweep as engine_run_sweep

    worker_data, params0, eval_fn, loss_fn = mnist_setup(U=U, K=K, iid=iid)
    cfg = FLConfig(aggregator=agg, scheduler=scheduler, rounds=rounds,
                   eval_every=eval_every,
                   obcsaa=obcsaa or OBCSAAConfig(chunk=4096, measure=1024,
                                                 topk=80, biht_iters=25),
                   topk_dense=topk_dense, error_feedback=error_feedback)
    t0 = time.time()
    out = engine_run_sweep(cfg, loss_fn, params0, worker_data,
                           np.full(U, float(K)), eval_fn=eval_fn,
                           rounds=rounds, eval_every=eval_every,
                           seeds=list(seeds), noise_var=noise_var,
                           p_max=p_max, lr=lr)
    wall = time.time() - t0
    A = out["accuracy"].shape[0]
    out.update({
        "wall_s": wall,
        "final_acc": out["accuracy"][:, -1],
        "final_loss": out["loss"][:, -1],
        "us_per_round": 1e6 * wall / (rounds * A),
    })
    return out


def acc_summary(out) -> str:
    """``acc=…;loss=…`` derived string for a sweep's per-arm finals:
    mean over arms, with the spread when the sweep has >1 arm."""
    acc, loss = out["final_acc"], out["final_loss"]
    s = f"acc={np.mean(acc):.4f};loss={np.mean(loss):.4f}"
    if len(acc) > 1:
        s += f";arms={len(acc)};acc_std={np.std(acc):.4f}"
    return s


def emit(rows: List[tuple]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)


# --- suite-level result cache (figures are expensive on CPU; the final
# ``python -m benchmarks.run | tee bench_output.txt`` replays from cache) ---

import json
from pathlib import Path

CACHE_PATH = Path(__file__).resolve().parents[1] / "experiments" / \
    "bench_cache.json"


def cached_rows(key: str):
    """Rows cached under ``key`` in experiments/bench_cache.json, or None.
    The single owner of the cache-file schema (a name/us/derived row list
    per suite key)."""
    if CACHE_PATH.exists():
        cache = json.loads(CACHE_PATH.read_text())
        if key in cache:
            return [tuple(r) for r in cache[key]]
    return None


def cached_suite(key: str, fn):
    """Run fn() -> rows once; replay from experiments/bench_cache.json."""
    rows = cached_rows(key)
    if rows is not None:
        emit(rows)
        return rows
    cache = {}
    if CACHE_PATH.exists():
        cache = json.loads(CACHE_PATH.read_text())
    rows = fn()
    cache[key] = [list(r) for r in rows]
    CACHE_PATH.parent.mkdir(parents=True, exist_ok=True)
    CACHE_PATH.write_text(json.dumps(cache, indent=1))
    return rows
