"""Decoder comparison at paper scale: wall-clock + NMSE per registry entry.

The paper's §V MLP has D = 50,890 parameters; with the block-diagonal
operator (DESIGN.md §4) that is 13 chunks of D_c = 4,096 measured with
S_c = 1,024 rows each. Two correlated FL rounds are simulated (shared
sparse signal + per-round innovation, U = 10 workers, eq. 6-13 with equal
weights) and every decoder reconstructs round 1; ``iht_warm`` additionally
consumes round 0's raw estimate — the temporal-correlation advantage the
warm start exists for (DESIGN.md §9).

Reported NMSE is direction error ||x̂/‖x̂‖ − x̄/‖x̄‖||² against the ideal
sparsified aggregate (1-bit measurements are scale-free; magnitude
tracking restores scale separately). The ``iht`` row is the einsum
reference and ``iht_fused`` the Pallas hot loop — the acceptance gate is
fused no slower than reference in interpret mode on CPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.obcsaa import OBCSAAConfig, compress_chunks
from repro.decode import DecodeConfig, decode

D = 50890            # paper §V MLP dimension
U = 10
ITERS = 10
# The warm start's value is iteration count, not asymptote: seeded with
# round t−1's estimate it reaches cold-start-at-ITERS quality in a fraction
# of the iterations (both decodes converge to the same fixed point if run
# long enough). The warm row therefore runs ITERS_WARM iterations.
ITERS_WARM = 4
# Fixed-step IHT stability: at the decode budget κ̄ = S_c/2 the restricted
# operator norm of Φ (S_c=1024, D_c=4096) is ≈3, so τ must sit below ~1/3;
# τ=1 is reserved for the exact-sparse regimes of the unit tests. NIHT
# needs no τ — that is its point.
TAU = 0.25


def _round_measurements(cfg, grads, phi):
    """eq. 6-13, equal weights, no AWGN: (y (n, S_c), x̄ chunks (n, D_c))."""
    pad = (-D) % cfg.chunk
    gpad = jnp.pad(grads, ((0, 0), (0, pad)))
    signs, _ = jax.vmap(lambda g: compress_chunks(cfg, g, phi))(gpad)
    y = jnp.mean(signs, axis=0)                       # eq. 12-13
    from repro.core.sparsify import topk_sparsify
    sp = jax.vmap(
        lambda g: topk_sparsify(g.reshape(-1, cfg.chunk), cfg.topk)[0])(gpad)
    return y, jnp.mean(sp, axis=0)


def setup(cfg, seed=0):
    """Two correlated rounds of worker gradients -> ((y0, x̄0), (y1, x̄1))."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    support = jax.random.choice(keys[0], D, (3000,), replace=False)
    base = jnp.zeros((D,)).at[support].set(
        jax.random.normal(keys[1], (3000,)))
    phi = cfg.phi()
    rounds = []
    for kw, kinn in ((keys[2], keys[3]), (keys[4], keys[5])):
        drift = base + 0.1 * jnp.zeros((D,)).at[support].set(
            jax.random.normal(kinn, (3000,)))
        grads = drift[None] + 0.05 * jax.random.normal(kw, (U, D))
        rounds.append(_round_measurements(cfg, grads, phi))
    return phi, rounds


def _nmse(xhat, xbar):
    a = xhat.reshape(-1)
    b = xbar.reshape(-1)
    a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
    b = b / jnp.maximum(jnp.linalg.norm(b), 1e-12)
    return float(jnp.sum((a - b) ** 2))


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main():
    cfg = OBCSAAConfig(chunk=4096, measure=1024, topk=409)
    k = cfg.decode_k
    phi, ((y0, _), (y1, xbar1)) = setup(cfg)

    cases = [
        ("iht", DecodeConfig("iht", iters=ITERS, tau=TAU), False),
        ("niht", DecodeConfig("niht", iters=ITERS), False),
        ("biht", DecodeConfig("biht", iters=ITERS), False),
        ("iht_fused", DecodeConfig("iht_fused", iters=ITERS, tau=TAU),
         False),
        ("iht_warm_it4", DecodeConfig("iht_warm", iters=ITERS_WARM,
                                      tau=TAU), True),
    ]
    # warm state: round 0's raw estimate from the same decoder family. Only
    # the warm row consumes it — the cold rows stay comparable to each other.
    warm_cfg = DecodeConfig("iht", iters=ITERS, tau=TAU)
    x0 = jax.jit(lambda y: decode(y, phi, k, warm_cfg))(y0)

    rows = []
    timings = {}
    for name, dc, warm in cases:
        if warm:
            fn = jax.jit(lambda y, x0, dc=dc: decode(y, phi, k, dc, x0=x0))
            args = (y1, x0)
        else:
            fn = jax.jit(lambda y, dc=dc: decode(y, phi, k, dc))
            args = (y1,)
        us = _time(fn, *args)
        xhat = fn(*args)
        timings[name] = us
        rows.append((f"decoders/{name}_D{D}_S{cfg.measure}", us,
                     f"nmse={_nmse(xhat, xbar1):.4f}"))
    speedup = timings["iht"] / max(timings["iht_fused"], 1e-9)
    rows.append((f"decoders/fused_vs_einsum_D{D}", timings["iht_fused"],
                 f"speedup={speedup:.2f}x"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
