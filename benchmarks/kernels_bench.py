"""Microbenchmarks of the OBCSAA compression pipeline (jnp path on CPU;
the Pallas kernels are structural/TPU-targeted and validated in tests)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.obcsaa import OBCSAAConfig, compress_chunks, reconstruct_chunks


def timeit(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def main():
    rows = []
    for D in (1 << 16, 1 << 20):
        cfg = OBCSAAConfig(chunk=4096, measure=1024, topk=409, biht_iters=10)
        g = jax.random.normal(jax.random.PRNGKey(0), (D,))
        comp = jax.jit(lambda g: compress_chunks(cfg, g))
        us = timeit(comp, g)
        rows.append((f"kernels/compress_D{D}", us,
                     f"ratio={D / (D // cfg.chunk * cfg.measure):.2f}"))
        signs, mags = comp(g)
        rec = jax.jit(lambda y, m: reconstruct_chunks(cfg, y, m))
        us = timeit(rec, signs, mags)
        rows.append((f"kernels/biht10_D{D}", us, ""))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
