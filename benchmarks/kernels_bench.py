"""Microbenchmarks of the OBCSAA compression pipeline (jnp path on CPU;
the Pallas kernels are structural/TPU-targeted and validated in tests).

Packed-codec rows (DESIGN.md §13): each geometry runs the f32 ±1 and the
uint32 bit-packed compress side by side and reports

- ``packed_bitwise`` — unpack(packed signs) == f32 signs, elementwise.
  This is a DETERMINISTIC flag (CI greps it; timing ratios are
  load-sensitive and never gate anything).
- ``bytes_f32`` / ``bytes_packed`` / ``wire_ratio`` — measurement-symbol
  bytes moved on the uplink per worker per round (static accounting, the
  32x the codec exists for).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.obcsaa import (OBCSAAConfig, compress_chunks,
                               reconstruct_chunks)
from repro.kernels.sign import unpack_signs


def timeit(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def main():
    rows = []
    for D in (1 << 16, 1 << 20):
        cfg = OBCSAAConfig(chunk=4096, measure=1024, topk=409, biht_iters=10)
        cfg_p = OBCSAAConfig(chunk=4096, measure=1024, topk=409,
                             biht_iters=10, packed=True)
        g = jax.random.normal(jax.random.PRNGKey(0), (D,))
        comp = jax.jit(lambda g: compress_chunks(cfg, g))
        us = timeit(comp, g)
        rows.append((f"kernels/compress_D{D}", us,
                     f"ratio={D / (D // cfg.chunk * cfg.measure):.2f}"))
        signs, mags = comp(g)
        comp_p = jax.jit(lambda g: compress_chunks(cfg_p, g))
        us_p = timeit(comp_p, g)
        packed, _ = comp_p(g)
        bitwise = bool(jnp.all(unpack_signs(packed) == signs))
        n_sym = signs.shape[0] * cfg.measure
        bytes_f32 = 4 * n_sym
        bytes_packed = n_sym // 8
        rows.append((f"kernels/compress_packed_D{D}", us_p,
                     f"packed_bitwise={bitwise};bytes_f32={bytes_f32};"
                     f"bytes_packed={bytes_packed};"
                     f"wire_ratio={bytes_f32 / bytes_packed:.1f}"))
        rec = jax.jit(lambda y, m: reconstruct_chunks(cfg, y, m))
        us = timeit(rec, signs, mags)
        rows.append((f"kernels/biht10_D{D}", us, ""))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
