"""Roofline analysis (deliverable g): derive the three terms per
(arch x shape x mesh) from the dry-run artifacts.

  compute    = HLO_FLOPs / peak_FLOPs            (per device)
  memory     = HLO_bytes / HBM_bw                (per device)
  collective = wire_bytes / (links x link_bw)    (per device)

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
MODEL_FLOPS = 6·N·D (dense; N_active for MoE) for the useful-compute ratio.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link
N_LINKS = 4                # 2D torus: 4 links per chip

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def active_params(cfg) -> int:
    """Activated parameters per token (MoE: shared + top-k of routed)."""
    n = cfg.param_count()
    if cfg.moe is None:
        return n
    m = cfg.moe
    n_mats = 3 if cfg.gated_mlp else 2
    per_expert = n_mats * cfg.d_model * cfg.d_ff
    routed_total = cfg.num_layers * m.num_experts * per_expert
    routed_active = cfg.num_layers * m.top_k * per_expert
    return n - routed_total + routed_active


def model_flops(cfg, shape, kind: str) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for inference."""
    na = active_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * na * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * na * tokens
    return 2.0 * na * shape.global_batch      # decode: one token per seq


def analyze(rec: dict) -> dict:
    from benchmarks.analytic import bytes_per_device, flops_per_device
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    agg = rec.get("agg") or "mean"
    n_dev = rec["n_devices"]
    # compute/memory: analytic napkin models (XLA aggregate cost_analysis
    # counts scan bodies once — see analytic.py); collectives: exact HLO
    # parse with while trip-count scaling.
    flops_dev = flops_per_device(cfg, shape, n_dev, agg)
    bytes_dev = bytes_per_device(cfg, shape, n_dev, agg)
    wire = rec["collectives"].get("total_wire_bytes",
                                  rec["collectives"]["total_bytes"])
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = wire / (N_LINKS * LINK_BW)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf_dev = model_flops(cfg, shape, shape.kind) / n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": dom.replace("_s", ""),
        "model_flops_per_dev": mf_dev,
        "useful_ratio": round(mf_dev / flops_dev, 3) if flops_dev else None,
        "hlo_flops_dev": rec["cost"].get("flops", 0.0),
        "step_time_bound_s": round(max(terms.values()), 6),
    }


def signal_path_rows():
    """Bytes moved through the 1-bit signal path, f32 vs packed codec
    (DESIGN.md §13) — STATIC accounting from the paper geometry, no
    dry-run artifacts needed, so the flags are deterministic for CI.

    Projection writes the sign measurements (f32 4 B/sym → packed
    1/8 B/sym: 32x); backprojection reads the sign-consistency residual
    (f32 4 B/sym → two uint32 bit-planes, 1/4 B/sym: 16x). Both clear the
    ≥4x reduction bar (``ge4`` flag)."""
    rows = []
    n_chunks, S = 13, 1024          # paper §V: D=50,890, D_c=4096, S_c=1024
    n_sym = n_chunks * S
    for name, f32_b, packed_b in (
            ("projection_out", 4 * n_sym, n_sym // 8),
            ("backprojection_resid_in", 4 * n_sym, 2 * (n_sym // 8))):
        ratio = f32_b / packed_b
        rows.append((f"roofline/signal_bytes/{name}", float(packed_b),
                     f"bytes_f32={f32_b};bytes_packed={packed_b};"
                     f"ratio={ratio:.1f};ge4={ratio >= 4.0}"))
    return rows


def main():
    rows = signal_path_rows()
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            for mesh_tag in ("single",):
                for agg in ("obcsaa", "mean"):
                    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh_tag}__{agg}.json"
                    if not p.exists():
                        continue
                    rec = json.loads(p.read_text())
                    if rec.get("status") != "ok":
                        rows.append((f"roofline/{arch}/{shape}/{agg}", 0.0,
                                     rec.get("status")))
                        continue
                    a = analyze(rec)
                    rows.append((
                        f"roofline/{arch}/{shape}/{agg}",
                        a["step_time_bound_s"] * 1e6,
                        f"bottleneck={a['bottleneck']};"
                        f"compute={a['compute_s']:.4f}s;"
                        f"memory={a['memory_s']:.4f}s;"
                        f"collective={a['collective_s']:.4f}s;"
                        f"useful={a['useful_ratio']}"))
                    break   # one agg per pair in the table
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
