"""Beyond-paper ablations (not in the paper — framework extensions):

- error feedback (Stich et al., paper ref [37]) on top of OBCSAA
- non-iid worker data (label-skewed partitions)
- scheduler comparison under low SNR (where scheduling matters most)

All rows run on the scan engine (DESIGN.md §11) with seeds as batched
arms; the static toggles (EF, iid, scheduler) select engine builds, and
the low-SNR pair shares its seeds axis within each build.
"""
from __future__ import annotations

from benchmarks.common import acc_summary, run_fl_sweep, emit
from repro.core.obcsaa import OBCSAAConfig

ROUNDS = 80
SEEDS = (0, 1)


def _sweep(rounds, *, ef=False, iid=True, scheduler="all", noise=1e-4):
    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25,
                      noise_var=noise)
    return run_fl_sweep("obcsaa", rounds=rounds, U=10, K=1000,
                        scheduler=scheduler, obcsaa=ob, seeds=SEEDS,
                        error_feedback=ef, iid=iid,
                        eval_every=rounds - 1)


def main(rounds=ROUNDS):
    rows = []
    base = _sweep(rounds)
    ef = _sweep(rounds, ef=True)
    rows.append(("ablate/obcsaa", base["us_per_round"], acc_summary(base)))
    d = float(ef["final_acc"].mean() - base["final_acc"].mean())
    rows.append(("ablate/obcsaa_ef", ef["us_per_round"],
                 f"{acc_summary(ef)};delta={d:+.4f}"))
    noniid = _sweep(rounds, iid=False)
    rows.append(("ablate/obcsaa_noniid", noniid["us_per_round"],
                 acc_summary(noniid)))
    # low-SNR scheduling: ADMM-scheduled vs all-in
    allin = _sweep(rounds, noise=1e-1)
    sched = _sweep(rounds, noise=1e-1, scheduler="admm_batched")
    rows.append(("ablate/lowsnr_all", allin["us_per_round"],
                 acc_summary(allin)))
    rows.append(("ablate/lowsnr_admm", sched["us_per_round"],
                 acc_summary(sched)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
