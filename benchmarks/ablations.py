"""Beyond-paper ablations (not in the paper — framework extensions):

- error feedback (Stich et al., paper ref [37]) on top of OBCSAA
- non-iid worker data (label-skewed partitions)
- scheduler comparison under low SNR (where scheduling matters most)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, mnist_setup, run_fl
from repro.core.obcsaa import OBCSAAConfig
from repro.data import load_mnist, partition_workers
from repro.fl import FederatedTrainer, FLConfig
from repro.models.mlp_mnist import (init_mlp_mnist, mlp_mnist_accuracy,
                                    mlp_mnist_loss)

ROUNDS = 80


def _run(agg, rounds, *, ef=False, iid=True, scheduler="all", noise=1e-4,
         U=10, K=1000):
    xtr, ytr, xte, yte = load_mnist()
    wx, wy = partition_workers(xtr, ytr, U, K, iid=iid, seed=0)
    wd = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    p0 = init_mlp_mnist(jax.random.PRNGKey(0))
    xe, ye = jnp.asarray(xte[:2000]), jnp.asarray(yte[:2000])
    ev = jax.jit(lambda p: (mlp_mnist_loss(p, xe, ye),
                            mlp_mnist_accuracy(p, xe, ye)))

    def loss_fn(p, d):
        return mlp_mnist_loss(p, d["x"], d["y"])

    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25,
                      noise_var=noise)
    cfg = FLConfig(aggregator=agg, scheduler=scheduler, rounds=rounds,
                   eval_every=rounds - 1, obcsaa=ob, error_feedback=ef)
    tr = FederatedTrainer(cfg, loss_fn, p0, wd, np.full(U, float(K)),
                          eval_fn=ev)
    logs = tr.run()
    return logs[-1]


def main(rounds=ROUNDS):
    rows = []
    base = _run("obcsaa", rounds)
    ef = _run("obcsaa", rounds, ef=True)
    rows.append(("ablate/obcsaa", 0.0, f"acc={base.accuracy:.4f}"))
    rows.append(("ablate/obcsaa_ef", 0.0,
                 f"acc={ef.accuracy:.4f};delta={ef.accuracy-base.accuracy:+.4f}"))
    noniid = _run("obcsaa", rounds, iid=False)
    rows.append(("ablate/obcsaa_noniid", 0.0, f"acc={noniid.accuracy:.4f}"))
    # low-SNR scheduling: ADMM-scheduled vs all-in
    allin = _run("obcsaa", rounds, noise=1e-1)
    sched = _run("obcsaa", rounds, noise=1e-1, scheduler="admm")
    rows.append(("ablate/lowsnr_all", 0.0, f"acc={allin.accuracy:.4f}"))
    rows.append(("ablate/lowsnr_admm", 0.0, f"acc={sched.accuracy:.4f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
