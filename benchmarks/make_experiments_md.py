"""Assemble experiments/EXPERIMENTS.md: decoder comparison (from the
decoders_bench suite), §Dry-run and §Roofline (from the dry-run JSONs).

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import DRYRUN_DIR, analyze
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

OUT = Path(__file__).resolve().parents[1] / "experiments" / "EXPERIMENTS.md"


def load(arch, shape, mesh_tag, aggs=("obcsaa", "mean")):
    for agg in aggs:
        p = DRYRUN_DIR / f"{arch}__{shape}__{mesh_tag}__{agg}.json"
        if p.exists():
            return json.loads(p.read_text())
    return None


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _not_yet_run_note(missing: list, present: list) -> str:
    """Annotation for arch×shape combos with no dry-run artifact:
    ``experiments/dryrun/`` is generated locally (it is .gitignored), so
    an absent JSON means the combination has not been run in this
    environment — NOT that it is broken. Archs with some artifacts are
    called out separately so the note never contradicts rows above."""
    if not missing:
        return ""
    have = {a for a, _ in present}
    full = sorted({a for a, _ in missing} - have)
    partial = sorted({a for a, _ in missing} & have)
    note = ("\n\nDry-run artifacts under `experiments/dryrun/` are "
            "generated locally via `python -m repro.launch.dryrun` and "
            "not committed; combinations without one are not yet run in "
            "this environment, not broken.")
    if full:
        note += (" No artifacts yet: "
                 + ", ".join(f"`{a}`" for a in full) + ".")
    for a in partial:
        n_miss = sum(1 for x, _ in missing if x == a)
        note += f" Partially run: `{a}` ({n_miss} combos remaining)."
    return note


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | status | temp/dev | HLO GFLOPs/dev | "
             "coll wire/dev | compile |",
             "|---|---|---|---|---|---|---|---|"]
    missing, present = [], []
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            for tag in ("single", "multi"):
                rec = load(arch, shape, tag)
                if rec is None:
                    missing.append((arch, (shape, tag)))
                    continue
                present.append((arch, (shape, tag)))
                if rec["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {tag} | skipped "
                                 f"(sub-quadratic rule) | | | | |")
                    continue
                if rec["status"] == "error":
                    lines.append(f"| {arch} | {shape} | {tag} | ERROR: "
                                 f"{rec['error'][:60]} | | | | |")
                    continue
                m = rec["memory"]
                c = rec["collectives"]
                lines.append(
                    f"| {arch} | {shape} | {tag} | ok | "
                    f"{fmt_bytes(m['temp_bytes'])} | "
                    f"{rec['cost'].get('flops', 0)/1e9:.1f} | "
                    f"{fmt_bytes(c.get('total_wire_bytes', c['total_bytes']))} | "
                    f"{rec['compile_s']}s |")
    return "\n".join(lines) + _not_yet_run_note(missing, present)


def roofline_table() -> str:
    lines = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
             "bottleneck | useful ratio | bound step(s) |",
             "|---|---|---|---|---|---|---|---|"]
    missing, present = [], []
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            rec = load(arch, shape, "single")
            if rec is None:
                missing.append((arch, shape))
                continue
            present.append((arch, shape))
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"{rec['status']} | - | - |")
                continue
            a = analyze(rec)
            lines.append(
                f"| {arch} | {shape} | {a['compute_s']:.4f} | "
                f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
                f"**{a['bottleneck']}** | {a['useful_ratio']} | "
                f"{a['step_time_bound_s']:.4f} |")
    return "\n".join(lines) + _not_yet_run_note(missing, present)


def decoder_rows():
    """decoders_bench rows, replayed from experiments/bench_cache.json
    (quick-set key preferred, deterministically) or run-and-cached via
    ``cached_suite`` — the same cache benchmarks.run writes."""
    from benchmarks.common import cached_rows, cached_suite
    for key in ("decoders:r60", "decoders:r300"):
        rows = cached_rows(key)
        if rows is not None:
            return rows
    from benchmarks import decoders_bench
    return cached_suite("decoders:r60", decoders_bench.main)


def decoder_table() -> str:
    lines = ["| decoder | us/decode | result |", "|---|---|---|"]
    for name, us, derived in decoder_rows():
        lines.append(f"| {name.split('/', 1)[-1]} | {us:,.0f} | "
                     f"{derived or '-'} |")
    return "\n".join(lines)


def sched_rows():
    """sched_bench rows, replayed from experiments/bench_cache.json or run
    fresh once and cached (same policy as the decoder table)."""
    from benchmarks.common import cached_rows, cached_suite
    rows = cached_rows("sched:v1")
    if rows is not None:
        return rows
    from benchmarks import sched_bench
    return cached_suite("sched:v1", sched_bench.main)


def sched_table() -> str:
    lines = ["| solver | us/instance | result |", "|---|---|---|"]
    for name, us, derived in sched_rows():
        lines.append(f"| {name.split('/', 1)[-1]} | {us:,.0f} | "
                     f"{derived or '-'} |")
    return "\n".join(lines)


def engine_rows():
    """engine_bench rows, replayed from experiments/bench_cache.json or
    run fresh once and cached (same policy as the sched table)."""
    from benchmarks.common import cached_rows, cached_suite
    rows = cached_rows("engine:v1")
    if rows is not None:
        return rows
    from benchmarks import engine_bench
    return cached_suite("engine:v1", engine_bench.main)


def engine_table() -> str:
    lines = ["| run | us/arm-round | result |", "|---|---|---|"]
    for name, us, derived in engine_rows():
        lines.append(f"| {name.split('/', 1)[-1]} | {us:,.0f} | "
                     f"{derived or '-'} |")
    return "\n".join(lines)


def theory_rows():
    """theory_bench rows, replayed from experiments/bench_cache.json or
    run fresh once and cached (same policy as the engine table)."""
    from benchmarks.common import cached_rows, cached_suite
    rows = cached_rows("theory:v1")
    if rows is not None:
        return rows
    from benchmarks import theory_bench
    return cached_suite("theory:v1", theory_bench.main)


def theory_table() -> str:
    lines = ["| run | us/arm-round | result |", "|---|---|---|"]
    for name, us, derived in theory_rows():
        lines.append(f"| {name.split('/', 1)[-1]} | {us:,.0f} | "
                     f"{derived or '-'} |")
    return "\n".join(lines)


def zoo_rows():
    """zoo_bench rows: CI-scale surrogate rows under ``zoo:v1`` and
    real-backward rows under ``zoo:v3`` (state-carry API: the parity
    gates cover optimizer moments + EF residuals), plus the ≥1B rows
    under ``zoo:v1:full`` / ``zoo:v2:full`` (regenerated by
    ``python -m benchmarks.zoo_bench --full``), all from
    experiments/bench_cache.json; run fresh once if the cache is
    empty."""
    from benchmarks.common import cached_rows
    from benchmarks.zoo_bench import FULL_KEY, TRAIN_FULL_KEY, TRAIN_KEY
    rows = cached_rows("zoo:v1")
    if rows is None:
        from benchmarks import zoo_bench
        return zoo_bench.main()
    return (rows + (cached_rows(TRAIN_KEY) or [])
            + (cached_rows(FULL_KEY) or [])
            + (cached_rows(TRAIN_FULL_KEY) or []))


def zoo_table() -> str:
    lines = ["| config | s/round | result |", "|---|---|---|"]
    for name, us, derived in zoo_rows():
        # keep the zoo-train/ prefix: it is what distinguishes the
        # real-backward rows from their surrogate-gradient twins
        shown = name[len("zoo/"):] if name.startswith("zoo/") else name
        lines.append(f"| {shown} | {us / 1e6:,.2f} | {derived or '-'} |")
    return "\n".join(lines)


def serve_rows():
    """serve_bench rows: parity gates + 10k/100k SLO rows under
    ``serve:v1`` plus the 1M-cell row under ``serve:v1:full``
    (regenerated by ``python -m benchmarks.serve_bench --full``), both
    from experiments/bench_cache.json; run fresh once if the cache is
    empty."""
    from benchmarks.common import cached_rows
    rows = cached_rows("serve:v1")
    if rows is None:
        from benchmarks import serve_bench
        return serve_bench.main()
    from benchmarks.serve_bench import FULL_KEY
    return rows + (cached_rows(FULL_KEY) or [])


def serve_table() -> str:
    lines = ["| run | mean ms/tick | result |", "|---|---|---|"]
    for name, us, derived in serve_rows():
        lines.append(f"| {name.split('/', 1)[-1]} | {us / 1e3:,.1f} | "
                     f"{derived or '-'} |")
    return "\n".join(lines)


def packed_table() -> str:
    """Bytes moved through the 1-bit signal path, f32 vs the packed uint32
    codec (DESIGN.md §13) — static accounting at paper geometry
    (D=50,890, D_c=4096, S_c=1024), deterministic by construction."""
    from benchmarks.roofline import signal_path_rows
    from repro.core.obcsaa import OBCSAAConfig, comm_stats
    lines = ["| path | f32 bytes | packed bytes | reduction | >=4x |",
             "|---|---|---|---|---|"]
    for name, _, derived in signal_path_rows():
        d = dict(kv.split("=", 1) for kv in derived.split(";"))
        lines.append(f"| {name.split('/')[-1]} | {d['bytes_f32']} | "
                     f"{d['bytes_packed']} | {d['ratio']}x | {d['ge4']} |")
    st = comm_stats(OBCSAAConfig(chunk=4096, measure=1024, topk=409),
                    D=50890)
    lines.append(f"| uplink_per_worker_per_round | "
                 f"{st['uplink_bits_f32'] // 8} | "
                 f"{st['uplink_bits_packed'] // 8} | "
                 f"{st['packed_wire_ratio']:.1f}x | "
                 f"{st['packed_wire_ratio'] >= 4.0} |")
    return "\n".join(lines)


def main():
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(
        "# EXPERIMENTS — generated by `python -m "
        "benchmarks.make_experiments_md`\n\n"
        "## Decoder comparison (repro.decode registry, paper-scale "
        "D=50,890 / S_c=1,024, CPU interpret mode)\n\n"
        "NMSE is direction error against the ideal sparsified aggregate "
        "(lower is better); `iht` is the einsum reference, `iht_fused` the "
        "Pallas hot loop (bit-identical output), `iht_warm_it4` is seeded "
        "with the previous round's estimate and runs 4 iterations "
        "(DESIGN.md §9).\n\n" + decoder_table()
        + "\n\n## P2 scheduling throughput (repro.sched, DESIGN.md §10)\n\n"
        "Instances/sec per solver path; `admm_speedup` is the acceptance "
        "gate (batched jitted ADMM ≥100× the per-instance NumPy loop at "
        "B=1024, U=64, per-instance parity alongside); the Pallas prefix "
        "sweep is bit-for-bit with the jnp path in interpret mode.\n\n"
        + sched_table()
        + "\n\n## FL engine throughput (repro.engine, DESIGN.md §11)\n\n"
        "16-arm × 50-round MNIST-MLP sweep (error feedback + warm start, "
        "ADMM scheduling every round): the scan×vmap engine vs the PR-3 "
        "host loop vendored verbatim in benchmarks/engine_bench.py "
        "(`speedup` is the ≥20× acceptance gate; "
        "`speedup_vs_live_legacy` isolates orchestration by rerunning the "
        "same legacy loop on today's accelerated selection kernels; "
        "parity rows are the CI-asserted invariants).\n\n"
        + engine_table()
        + "\n\n## Theorem-1 bound vs measured trajectory "
        "(repro.theory, DESIGN.md §12)\n\n"
        "Predicted per-round R_t (eq. 24, the `ErrorBudget` scan outputs; "
        "analysis constant G instantiated from the actual initial worker "
        "gradients) against the measured aggregation error ‖ĝ−ḡ‖² probe, "
        "BOTH SNR arms from ONE `run_sweep` call on the MNIST-MLP task — "
        "`bound_ge_measured` must hold at every logged round. The tuner "
        "rows sweep the (κ_c, S_c) grid over the closed form under the "
        "paper's uplink symbol budget: its win over the RIP-infeasible "
        "mistuned design is judged on the bound's own prediction target "
        "(measured aggregation error; final loss/acc reported alongside — "
        "eq. 19's worst-case sparsification term is nearly flat in κ at "
        "MLP scale, so the actionable tuner signal is the C(δ) "
        "feasibility cut, DESIGN.md §12).\n\n"
        + theory_table()
        + "\n\n## Packed 1-bit uplink codec (kernels, DESIGN.md §13)\n\n"
        "Bytes moved through the sign-measurement signal path, f32 ±1 vs "
        "the 32-per-word uint32 codec, at paper geometry (D=50,890, "
        "D_c=4096, S_c=1024; 13 chunks). Projection writes packed words "
        "straight from the kernel epilogue (32x); the BIHT residual rides "
        "two disjoint uint32 bit-planes (16x); the per-chunk magnitude "
        "scalar stays f32 in both codecs, so the end-to-end uplink ratio "
        "lands just under 32x. Packed is bit-for-bit equal to f32 through "
        "compress → MAC → decode (tests/test_packed.py), so the reduction "
        "is free.\n\n" + packed_table()
        + "\n\n## Sharded model-zoo FL rounds (repro.engine.zoo, "
        "DESIGN.md §14)\n\n"
        "One full OBCSAA round (grads → 1-bit compress → power control → "
        "packed int32 MAC + AWGN → chunked decode → update) with the "
        "parameter vector sharded over the 8-device host mesh (4 FL "
        "workers × 2 model shards); nothing dense at full D is ever "
        "replicated. `parity-16k` is the CI gate: the sharded round chain "
        "must stay BITWISE equal to the single-device reference oracle "
        "(`parity=True`). The `gemma2-2b-2.6B` row is the ≥1B-parameter "
        "acceptance run (full config, D=2.61B, wide-chunk geometry "
        "D_c=16384 / S_c=32 / κ_c=8) with measured rounds/sec; it is "
        "regenerated by `python -m benchmarks.zoo_bench --full` and "
        "replayed from the cache otherwise. The `zoo-train/*` rows are "
        "the REAL-backward counterparts (repro.engine.zoo_train, "
        "DESIGN.md §16): the same round driven by genuine eq. 3 "
        "gradients of the scanned stacked-layer model, computed "
        "parameter-sharded with cotangents landing directly in the "
        "owned (n_chunks, D_c) rows — no host round-trip, no full-D "
        "gather. `parity-gemma2-smoke` gates a multi-round REAL-gradient "
        "chain bitwise against the jitted single-device oracle; every "
        "row reports `peak_rss_mb` (peak process RSS of the isolated "
        "bench child — on the host-device mesh this IS the device "
        "memory bound) and a finite per-round loss. "
        "`zoo-train/gemma2-2b-2.6B` is the ≥1B real-backward acceptance "
        "row, cached under `zoo:v2:full` and regenerated by "
        "`--full`.\n\n" + zoo_table()
        + "\n\n## Fleet scheduling-service SLO (repro.serve, "
        "DESIGN.md §15)\n\n"
        "Steady-state serve loop — fade step → CSI reports → dirty set → "
        "pow2 compaction → batched solve → cache — at ρ=0.999, half the "
        "fleet reporting per tick, 5% movement threshold. p50/p99 are "
        "per-tick schedule latencies over the timed window after an "
        "untimed warm-up; `solved_per_s` counts schedules actually "
        "re-solved, `served_per_s` counts cells served (solved + cache "
        "hits). The two parity rows are the CI gates: the threshold-0 "
        "served cache is bitwise equal to a cold full-fleet solve (both "
        "solvers), and dual-warm-started ADMM converges to the same β "
        "bitwise as cold-start (iteration counts alongside — warm starts "
        "do NOT speed this solver up, see DESIGN.md §15). "
        "`primal_warm_iters` is honest telemetry for the opt-in "
        "`warm_beta` primal seed (cached β projected feasible, "
        "sched/admm.py): measured on correlated fades it saves ≤0.02 "
        "outer iterations over dual-only and forfeits the cold-parity "
        "guarantee, so the serve loop keeps carrying duals only. The "
        "1M-cell "
        "row is regenerated by `python -m benchmarks.serve_bench --full` "
        "and replayed from the cache otherwise.\n\n" + serve_table()
        + "\n\n## Dry-run table\n\n" + dryrun_table()
        + "\n\n## Roofline table (single-pod, 256 chips)\n\n"
        + roofline_table() + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
