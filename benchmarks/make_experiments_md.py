"""Assemble EXPERIMENTS.md §Dry-run and §Roofline from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import DRYRUN_DIR, analyze
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

OUT = Path(__file__).resolve().parents[1] / "experiments" / "tables.md"


def load(arch, shape, mesh_tag, aggs=("obcsaa", "mean")):
    for agg in aggs:
        p = DRYRUN_DIR / f"{arch}__{shape}__{mesh_tag}__{agg}.json"
        if p.exists():
            return json.loads(p.read_text())
    return None


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | status | temp/dev | HLO GFLOPs/dev | "
             "coll wire/dev | compile |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            for tag in ("single", "multi"):
                rec = load(arch, shape, tag)
                if rec is None:
                    lines.append(f"| {arch} | {shape} | {tag} | MISSING | "
                                 "| | | |")
                    continue
                if rec["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {tag} | skipped "
                                 f"(sub-quadratic rule) | | | | |")
                    continue
                if rec["status"] == "error":
                    lines.append(f"| {arch} | {shape} | {tag} | ERROR: "
                                 f"{rec['error'][:60]} | | | | |")
                    continue
                m = rec["memory"]
                c = rec["collectives"]
                lines.append(
                    f"| {arch} | {shape} | {tag} | ok | "
                    f"{fmt_bytes(m['temp_bytes'])} | "
                    f"{rec['cost'].get('flops', 0)/1e9:.1f} | "
                    f"{fmt_bytes(c.get('total_wire_bytes', c['total_bytes']))} | "
                    f"{rec['compile_s']}s |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
             "bottleneck | useful ratio | bound step(s) |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            rec = load(arch, shape, "single")
            if rec is None or rec["status"] != "ok":
                status = "-" if rec is None else rec["status"]
                lines.append(f"| {arch} | {shape} | - | - | - | {status} | "
                             "- | - |")
                continue
            a = analyze(rec)
            lines.append(
                f"| {arch} | {shape} | {a['compute_s']:.4f} | "
                f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
                f"**{a['bottleneck']}** | {a['useful_ratio']} | "
                f"{a['step_time_bound_s']:.4f} |")
    return "\n".join(lines)


def main():
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text("## Dry-run table\n\n" + dryrun_table()
                   + "\n\n## Roofline table (single-pod, 256 chips)\n\n"
                   + roofline_table() + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
