"""Scheduling throughput: P2 instances/sec across solver implementations.

The fleet question of DESIGN.md §10: how many (cell, round) P2 instances
per second can each path schedule?

- ``admm numpy``      — the per-instance float64 reference loop
                        (``repro.sched.reference.admm_solve``), timed over
                        a subsample and extrapolated per instance.
- ``admm batched``    — ``admm_solve_batched``: jitted chunked-scan ADMM
                        with convergence masking + compaction, B = 1024
                        instances per device call.
- ``greedy`` rows     — the loop reference vs the vectorized jnp prefix
                        sweep vs the Pallas prefix kernel at large U.

Acceptance gate (ISSUE 3): batched jitted ADMM ≥ 100× the NumPy loop's
instances/sec at B = 1024, U = 64, with per-instance parity (β equal, R_t
within float32 tolerance) — the ``admm_speedup`` row carries the measured
ratio and parity check; ``greedy_kernel_parity`` carries the bit-for-bit
interpret-mode check of the Pallas sweep against the jnp path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.theory import AnalysisConstants
from repro.kernels.prefix_eval import prefix_eval
from repro.sched import (BatchedProblem, Problem, SchedConfig,
                         admm_solve, admm_solve_batched, greedy_solve,
                         greedy_solve_batched)
from repro.sched.greedy import pack_coefs, prefix_sweep

B_ADMM, U_ADMM = 1024, 64      # the acceptance-gate shape
B_GREEDY, U_GREEDY = 64, 8192  # the Pallas prefix-sweep shape
NUMPY_SAMPLE = 24              # reference instances timed per solver
PARITY_SAMPLE = 12

CONST = AnalysisConstants(rho1=200.0, G=1.0)


def make_problem(U, seed):
    rng = np.random.default_rng(seed)
    return Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                   k_weights=np.full(U, 3000.0), p_max=10.0,
                   noise_var=1e-4, D=50890, S=1000, kappa=1000, const=CONST)


def _time(fn, reps=3):
    fn()                                   # warm (compile + bucket shapes)
    best = np.inf
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def _time_pair(fn_a, fn_b, trials=3):
    """Best-of timing with the two measurements interleaved, so transient
    CPU contention (the 2-core CI/container reality) hits both sides —
    the min over trials estimates each side's uncontended speed."""
    fn_a(), fn_b()                         # warm (compile + bucket shapes)
    best_a = best_b = np.inf
    for _ in range(trials):
        t0 = time.time()
        fn_a()
        best_a = min(best_a, time.time() - t0)
        t0 = time.time()
        fn_b()
        best_b = min(best_b, time.time() - t0)
    return best_a, best_b


def admm_rows():
    probs = [make_problem(U_ADMM, 10_000 + i) for i in range(B_ADMM)]
    bp = BatchedProblem.from_problems(probs)

    sample = probs[:NUMPY_SAMPLE]
    t_np, t_b = _time_pair(
        lambda: [admm_solve(p) for p in sample],
        lambda: jax.block_until_ready(admm_solve_batched(bp)))
    per_np = t_np / len(sample)
    rate_np = 1.0 / per_np
    rate_b = B_ADMM / t_b

    beta_b, bt_b, r_b = admm_solve_batched(bp)
    mismatches, r_rel = 0, 0.0
    for i in range(PARITY_SAMPLE):
        beta_n, _, r_n = admm_solve(probs[i])
        mismatches += not np.array_equal(np.asarray(beta_b[i]), beta_n)
        r_rel = max(r_rel, abs(float(r_b[i]) - r_n) / r_n)
    speedup = rate_b / rate_np
    return [
        (f"sched/admm_numpy_U{U_ADMM}", per_np * 1e6,
         f"rate={rate_np:.1f}/s"),
        (f"sched/admm_batched_B{B_ADMM}_U{U_ADMM}", t_b / B_ADMM * 1e6,
         f"rate={rate_b:.0f}/s"),
        (f"sched/admm_speedup_B{B_ADMM}_U{U_ADMM}", t_b * 1e6,
         f"speedup={speedup:.1f}x;gate>=100x;beta_mismatch="
         f"{mismatches}/{PARITY_SAMPLE};max_rel_R={r_rel:.1e}"),
    ]


def greedy_rows():
    probs = [make_problem(U_GREEDY, 20_000 + i) for i in range(B_GREEDY)]
    bp = BatchedProblem.from_problems(probs)

    sample = probs[:4]
    t_np = _time(lambda: [greedy_solve(p) for p in sample], reps=1)
    per_np = t_np / len(sample)

    t_v = _time(lambda: jax.block_until_ready(greedy_solve_batched(bp)))
    kcfg = SchedConfig(use_kernel=True)
    t_k = _time(lambda: jax.block_until_ready(
        greedy_solve_batched(bp, kcfg)))

    # bit-for-bit: jnp sweep vs Pallas kernel (interpret, full-extent tile)
    caps = bp.caps()
    order = jnp.argsort(-caps, axis=-1)
    caps_s = jnp.take_along_axis(caps, order, -1)
    k_s = jnp.take_along_axis(bp.k_weights, order, -1)
    coefs = pack_coefs(bp)
    r_jnp = jax.jit(prefix_sweep)(caps_s, k_s, coefs)
    r_ker = jax.jit(lambda a, b, c: prefix_eval(a, b, c, interpret=True))(
        caps_s, k_s, coefs)
    bitwise = bool(jnp.all(r_jnp == r_ker))
    return [
        (f"sched/greedy_numpy_U{U_GREEDY}", per_np * 1e6,
         f"rate={1.0 / per_np:.1f}/s"),
        (f"sched/greedy_vectorized_B{B_GREEDY}_U{U_GREEDY}",
         t_v / B_GREEDY * 1e6, f"rate={B_GREEDY / t_v:.0f}/s"),
        (f"sched/greedy_pallas_B{B_GREEDY}_U{U_GREEDY}",
         t_k / B_GREEDY * 1e6,
         f"rate={B_GREEDY / t_k:.0f}/s;bitwise_vs_jnp={bitwise}"),
    ]


def main():
    rows = admm_rows() + greedy_rows()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
