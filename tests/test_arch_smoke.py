"""Per-architecture smoke tests (deliverable f): reduced config, one forward
+ one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import build_model

B, S = 2, 64


def make_batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = 0.01 * jnp.ones(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    exp_s = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nan(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)

    def loss_of(p):
        return model.loss_fn(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert bool(jnp.isfinite(loss))
    new = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                                 params, grads)
    loss2 = jax.jit(loss_of)(new)
    assert bool(jnp.isfinite(loss2))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch, built):
    cfg, model, params = built(arch)
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, tok,
                                                   jnp.int32(5))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)
