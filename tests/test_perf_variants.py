"""The §Perf variants must compute the same functions as the baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.sparsify import topk_sparsify, topk_sparsify_bisect
from repro.models import build_model


def test_bisect_topk_matches_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
    for k in (1, 7, 64, 400):
        a, ma = topk_sparsify(x, k)
        b, mb = topk_sparsify_bisect(x, k)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_bisect_topk_handles_zero_rows():
    x = jnp.zeros((4, 128))
    _, m = topk_sparsify_bisect(x, 5)
    # all-zero rows: every |x| >= 0 threshold -> full mask; harmless since
    # the values are zero — selected VALUES are what is transmitted
    v = x * m
    assert float(jnp.abs(v).sum()) == 0.0


@pytest.mark.parametrize("arch", ["gemma3-27b", "gemma2-2b", "mixtral-8x22b"])
def test_flash_decode_matches_baseline(arch):
    """decode_sharded_chunks (partial-softmax attention) is numerically
    equivalent to the gather-based decode."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    cfg_opt = dataclasses.replace(cfg, decode_sharded_chunks=4)
    m0, m1 = build_model(cfg), build_model(cfg_opt)
    params = m0.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    cache0 = m0.init_cache(B, S)
    cache1 = m1.init_cache(B, S)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    d0 = jax.jit(m0.decode_step)
    d1 = jax.jit(m1.decode_step)
    for pos in range(6):
        l0, cache0 = d0(params, cache0, toks[:, pos:pos + 1], jnp.int32(pos))
        l1, cache1 = d1(params, cache1, toks[:, pos:pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32),
                                   rtol=2e-4, atol=2e-4)
