"""Memory-mapped token shards + deterministic per-worker sampling
(repro.data.tokens, DESIGN.md §17) — the --data path of the zoo-train
CLI: write/open round-trip, fold_in-keyed determinism (same (key, t)
draws the same batch, no iterator state to serialize for resume), and
the loud alignment/window validation messages."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.data import TokenShards, token_stream, write_token_shards
from repro.data.tokens import META_NAME

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _corpus(tmp_path, n_shards=3, n_tokens=257, vocab=101):
    rng = np.random.default_rng(0)
    shards = [rng.integers(0, vocab, size=n_tokens + 13 * i).astype(np.int32)
              for i in range(n_shards)]
    d = str(tmp_path / "toks")
    TokenShards.write(d, shards)
    return d, shards


def test_write_open_roundtrip(tmp_path):
    d, shards = _corpus(tmp_path)
    ts = TokenShards.open(d)
    assert ts.total_tokens == sum(s.size for s in shards)
    assert list(ts.lengths) == [s.size for s in shards]
    for mm, s in zip(ts.memmaps, shards):
        assert np.array_equal(np.asarray(mm), s)
    # module-level alias writes the identical format
    d2 = write_token_shards(str(tmp_path / "toks2"), shards)
    assert TokenShards.open(d2).total_tokens == ts.total_tokens


def test_sampling_deterministic_and_next_token(tmp_path):
    """Same (key, t) -> the same (U, B, S) batch on every call (resume
    needs no data-iterator state); different rounds and workers draw
    different windows; targets are the next-token shift of tokens."""
    d, _ = _corpus(tmp_path)
    ts = TokenShards.open(d)
    key = jax.random.PRNGKey(5)
    U, B, S = 3, 4, 16
    b1 = ts.sample_zoo_batch(key, 7, U, B, S)
    b2 = ts.sample_zoo_batch(key, 7, U, B, S)
    assert b1["tokens"].shape == (U, B, S)
    for k in b1:
        assert np.array_equal(b1[k], b2[k]), k
    b3 = ts.sample_zoo_batch(key, 8, U, B, S)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert not np.array_equal(b1["tokens"][0], b1["tokens"][1])
    # next-token contract: window of S+1, split as [: -1] / [1:]
    assert np.array_equal(b1["tokens"][..., 1:], b1["targets"][..., :-1])


def test_open_missing_meta_message(tmp_path):
    with pytest.raises(FileNotFoundError,
                       match=rf"has no {META_NAME}; --data expects"):
        TokenShards.open(str(tmp_path / "empty"))


def test_open_missing_shard_message(tmp_path):
    d, _ = _corpus(tmp_path)
    os.remove(os.path.join(d, "shard_00001.tokens"))
    with pytest.raises(FileNotFoundError,
                       match=r"shard_00001\.tokens.*missing"):
        TokenShards.open(d)


def test_misaligned_shard_message(tmp_path):
    """A shard whose byte size is not a whole number of tokens is
    truncated or was written with a different dtype — it must fail
    loudly at open, not shift every later token (DESIGN.md §17)."""
    d, _ = _corpus(tmp_path)
    p = os.path.join(d, "shard_00000.tokens")
    with open(p, "ab") as f:
        f.write(b"\x00\x01\x02")     # 3 stray bytes: not a whole int32
    with pytest.raises(ValueError,
                       match=r"shard_00000\.tokens.*is misaligned: "
                             r"\d+ bytes is not a whole positive number "
                             r"of int32 tokens"):
        TokenShards.open(d)


def test_wrong_meta_dtype_is_misaligned(tmp_path):
    """Meta declaring a dtype the files were not written with trips the
    same alignment check (int32 payload vs int64 meta)."""
    d, _ = _corpus(tmp_path, n_shards=1, n_tokens=257)   # odd token count
    meta_p = os.path.join(d, META_NAME)
    meta = json.load(open(meta_p))
    meta["dtype"] = "int64"
    json.dump(meta, open(meta_p, "w"))
    with pytest.raises(ValueError, match=r"int64 tokens.*different "
                                         r"dtype"):
        TokenShards.open(d)


def test_short_shard_window_message(tmp_path):
    d = str(tmp_path / "short")
    TokenShards.write(d, [np.arange(10, dtype=np.int32)])
    ts = TokenShards.open(d)
    with pytest.raises(ValueError, match=r"holds 10 tokens but "
                                         r"seq_len=32.*windows of 33"):
        ts.sample_zoo_batch(jax.random.PRNGKey(0), 0, 2, 2, 32)


@pytest.mark.slow
def test_zoo_train_cli_data_resume(tmp_path):
    """The wired CLI path: --zoo-train --data --optimizer adam
    --error-feedback trains off the token shards, checkpoints the FULL
    carry (master + moments + residuals + t_next), and --resume finishes
    bit-for-bit identical to the uninterrupted run — per-round batches
    are re-sampled from the absolute round index, so the data stream
    needs no serialized state (DESIGN.md §17)."""
    from repro import checkpoint
    tok, _ = token_stream(4, 700, 100, seed=3)
    d = write_token_shards(str(tmp_path / "toks"), list(np.asarray(tok)))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "gemma2-2b", "--smoke", "--zoo-train", "--batch", "2",
            "--seq", "32", "--cs-chunk", "256", "--cs-measure", "64",
            "--cs-topk", "16", "--optimizer", "adam", "--error-feedback",
            "--data", d]

    def run(extra):
        r = subprocess.run(base + extra, env=env, capture_output=True,
                           text=True, timeout=560)
        assert r.returncode == 0, \
            f"ARGS {extra}\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
        return r.stdout

    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    run(["--steps", "4", "--ckpt-dir", da])
    run(["--steps", "2", "--ckpt-dir", db])
    out = run(["--steps", "4", "--ckpt-dir", db, "--resume"])
    assert "resumed zoo-train at round 2" in out
    a = np.load(os.path.join(checkpoint.step_dir(da, 4), "arrays.npz"))
    b = np.load(os.path.join(checkpoint.step_dir(db, 4), "arrays.npz"))
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        assert np.array_equal(a[k], b[k]), f"leaf {k} differs after resume"
