"""Real sharded backward passes (repro.engine.zoo_train, DESIGN.md §16).

The tentpole contract: genuine eq. 3 gradients of the scanned
stacked-layer model, computed parameter-sharded on the workers×model
mesh, must land bitwise-equal to the jitted single-device oracle — as
raw (U, n_chunks, D_c) gradients already in the compressor's layout, as
chained full rounds, and as the one-program multi-arm sweep (vs
``reference_sweep``, the oracle with the identical scan/map wrapping —
parity is per program structure). The in-process tier checks the scan
compilation itself (scanned ≡ unrolled layer stack, bitwise) and the
single-device host-mesh round; the 8-device subprocess test is the mesh
parity gate CI runs in the mesh-8 job."""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_smoke_config
from repro.core.obcsaa import OBCSAAConfig
from repro.engine.zoo import ZooRound
from repro.engine.zoo_train import build_zoo_train_round
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PARITY_OB = dict(chunk=256, measure=64, topk=16, biht_iters=3,
                 recon_alg="iht", spmd_topk=True, packed=True,
                 bisect_iters=16)


def test_zoo_train_round_host_mesh():
    """Single-device host mesh: the real-gradient round moves the master,
    reports a finite loss/budget, and ``grads_in_layout`` matches the
    jitted oracle bitwise (same shard_map code path, unit federation)."""
    cfg = get_smoke_config("mnist-mlp")
    model = build_model(cfg)
    mesh = make_host_mesh()
    zr = build_zoo_train_round(model, mesh, OBCSAAConfig(**PARITY_OB))
    params = model.init(jax.random.PRNGKey(0))
    chunked = zr.chunk_params(params)
    master = zr.shard_params(chunked)
    kx, ky = jax.random.split(jax.random.PRNGKey(3))
    raw = {"x": 0.1 * jax.random.normal(kx, (zr.U, 2, 784), jnp.float32),
           "y": jax.random.randint(ky, (zr.U, 2), 0, 10, jnp.int32)}
    batch = zr.shard_batch(raw)

    g, losses = zr.grads_in_layout(master, batch)
    gr, lref = zr.reference_grads(chunked, raw)
    assert np.array_equal(np.asarray(g), np.asarray(gr))
    assert np.array_equal(np.asarray(losses), np.asarray(lref))

    s2, st = zr.round_train(master, batch, 0, jax.random.PRNGKey(1),
                            1e-4, 10.0, 0.1)
    m2 = np.asarray(s2.master)
    assert np.isfinite(float(st.loss))
    assert np.isfinite(m2).all()
    assert not np.array_equal(m2, np.asarray(master))
    for name, term in zip(st.budget._fields, st.budget):
        assert np.isfinite(np.asarray(term)).all(), name
    # the round consumed REAL gradients: params round-trip finitely
    p2 = zr.params_from_master(s2)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(p2))


@pytest.mark.parametrize("opt,kw", [("momentum", {"beta": 0.9}),
                                    ("adam", {})])
def test_zoo_train_stateful_round_host_mesh(opt, kw):
    """Momentum/adam moments live as sharded (n_chunks, D_c) carries and
    the per-worker EF residual as a (U, n_chunks, D_c) grads-layout carry
    (DESIGN.md §17): a 2-round chain on the host mesh matches the jitted
    oracle bitwise on EVERY carry leaf, and the residual is live (the
    1-bit uplink drops mass, so it must be non-zero after a round)."""
    cfg = get_smoke_config("mnist-mlp")
    model = build_model(cfg)
    mesh = make_host_mesh()
    zr = build_zoo_train_round(model, mesh, OBCSAAConfig(**PARITY_OB),
                               optimizer=opt, opt_kwargs=kw,
                               error_feedback=True)
    params = model.init(jax.random.PRNGKey(0))
    chunked = zr.chunk_params(params)
    kx, ky = jax.random.split(jax.random.PRNGKey(3))
    raw = {"x": 0.1 * jax.random.normal(kx, (zr.U, 2, 784), jnp.float32),
           "y": jax.random.randint(ky, (zr.U, 2), 0, 10, jnp.int32)}
    batch = zr.shard_batch(raw)
    key = jax.random.PRNGKey(1)

    s = zr.shard_state(zr.init_state(chunked))
    r = zr.init_state(chunked)
    for t in range(2):
        s, st = zr.round_train(s, batch, t, key, 1e-4, 10.0, 0.1)
        r, rst = zr.reference_round_train(r, raw, t, key, 1e-4, 10.0, 0.1)
        for i, (a, b) in enumerate(zip(jax.tree_util.tree_leaves(s),
                                       jax.tree_util.tree_leaves(r))):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (t, i)
        assert np.isfinite(float(st.loss))
    assert float(np.abs(np.asarray(s.residual)).sum()) > 0.0


def test_zoo_train_state_validation_messages():
    """The carry is validated eagerly at the host entry points: a
    stateful round rejects bare masters, and the EF residual geometry
    errors name the expected (U, n_chunks, D_c) shape instead of dying
    as an opaque spec error inside shard_map (DESIGN.md §17)."""
    from repro.engine.zoo_train import ZooTrainState
    cfg = get_smoke_config("mnist-mlp")
    model = build_model(cfg)
    mesh = make_host_mesh()
    ob = OBCSAAConfig(**PARITY_OB)
    zr = build_zoo_train_round(model, mesh, ob, optimizer="adam",
                               error_feedback=True)
    chunked = zr.chunk_params(model.init(jax.random.PRNGKey(0)))
    want = (zr.U, zr.n_chunks, ob.chunk)

    # stateful round rejects a bare master array
    with pytest.raises(TypeError, match=r"optimizer='adam'.*stateful "
                                        r"moments/residuals"):
        zr.as_state(chunked)
    # EF on, residual missing
    bad = ZooTrainState(master=chunked, opt=zr.optimizer.init(chunked),
                        residual=None)
    with pytest.raises(ValueError, match=r"has no EF residual.*"
                                         r"\(U, n_chunks, D_c\)"):
        zr._check_state(bad)
    # EF on, residual with the wrong geometry
    bad = bad._replace(residual=jnp.zeros((1, 2, 3), jnp.float32))
    with pytest.raises(ValueError,
                       match=r"shape \(1, 2, 3\), expected"):
        zr._check_state(bad)
    # EF off, residual present
    zr2 = build_zoo_train_round(model, mesh, ob)
    full = ZooTrainState(master=chunked, opt=(),
                         residual=jnp.zeros(want, jnp.float32))
    with pytest.raises(ValueError, match=r"error_feedback=False.*WITH "
                                         r"an EF residual"):
        zr2._check_state(full)


def test_train_config_optimizer_and_ef_messages():
    """TrainConfig validates the optimizer name and the EF/aggregation
    coupling eagerly, naming the offending values (DESIGN.md §17)."""
    with pytest.raises(ValueError, match=r"optimizer='adamw' is not a "
                                         r"registered optimizer"):
        TrainConfig(optimizer="adamw")
    with pytest.raises(ValueError, match=r"error_feedback=True needs "
                                         r"aggregation='obcsaa'"):
        TrainConfig(aggregation="mean", error_feedback=True)
    TrainConfig(aggregation="obcsaa", error_feedback=True)   # fine


def test_scanned_vs_unrolled_layer_stack_bitwise():
    """The ``lax.scan`` over stacked per-layer params computes the SAME
    hidden states, bit for bit, as an unrolled per-layer chain of the
    identical CLOSED loop body (length-1 scans): the scan mixes nothing
    across layers. The closed body is load-bearing — an OPEN unrolled
    loop lets XLA fuse across layer boundaries and drifts final bf16
    ulps, the same per-structure parity contract as the round's decode
    blocks (DESIGN.md §16)."""
    from repro.configs.base import dtype_of
    from repro.dist.sharding import constrain
    from repro.models.layers import embed, rmsnorm
    from repro.models.transformer import (_apply_layer_full, layer_flags,
                                          lm_forward)

    cfg = get_smoke_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                                cfg.vocab_size, jnp.int32)
    flags = layer_flags(cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(carry, xs):
        # lm_forward's scan body (collect_cache off, no resolver)
        x, aux_acc = carry
        lp, fl = xs
        x = constrain(x, ("data", None, None))
        x, _, aux = _apply_layer_full(lp, x, cfg, fl, positions,
                                      params.get("shared_block"))
        return (x, aux_acc + aux), None

    @jax.jit
    def scanned(params):
        x, _, _ = lm_forward(params, cfg, tokens, remat=False,
                             return_hidden=True)
        return x

    @jax.jit
    def unrolled(params):
        x = embed(params["embedding"], tokens, dtype_of(cfg)) \
            * math.sqrt(cfg.d_model)
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(cfg.num_layers):
            xs = (jax.tree_util.tree_map(lambda a: a[i:i + 1],
                                         params["layers"]),
                  jax.tree_util.tree_map(lambda a: a[i:i + 1], flags))
            carry, _ = jax.lax.scan(body, carry, xs)
        return rmsnorm(carry[0], params["final_norm"], cfg.norm_eps)

    assert np.array_equal(np.asarray(scanned(params)),
                          np.asarray(unrolled(params)))


def test_train_config_packed_geometry_message():
    """cs_packed needs S_c % 32 == 0, validated EAGERLY at config
    construction with the offending field named (not as an opaque
    reshape error deep in the kernels)."""
    with pytest.raises(ValueError, match=r"cs_measure=100"):
        TrainConfig(cs_packed=True, cs_measure=100)
    TrainConfig(cs_packed=True, cs_measure=96)     # multiple of 32: fine
    TrainConfig(cs_packed=False, cs_measure=100)   # unpacked: no 32-rule


def test_zoo_round_n_chunks_geometry_message():
    """An explicit n_chunks that cannot cover D (or break mesh
    granularity) fails at construction, naming the offending value."""
    mesh = make_host_mesh()
    with pytest.raises(ValueError, match=r"n_chunks=3"):
        ZooRound(OBCSAAConfig(**PARITY_OB), 16000, mesh, n_chunks=3)
    ZooRound(OBCSAAConfig(**PARITY_OB), 16000, mesh, n_chunks=64)


SCRIPT_TRAIN_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine.zoo_train import build_zoo_train_round
    from repro.models.registry import build_model

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ob = OBCSAAConfig(chunk=256, measure=64, topk=16, biht_iters=3,
                      recon_alg="iht", spmd_topk=True, packed=True,
                      bisect_iters=16)
    cfg = get_smoke_config("gemma2-2b")
    model = build_model(cfg)
    zr = build_zoo_train_round(model, mesh, ob)
    assert (zr.U, zr.n_model) == (4, 2)
    params = model.init(jax.random.PRNGKey(0))
    chunked = zr.chunk_params(params)
    master = zr.shard_params(chunked)
    key = jax.random.PRNGKey(7)
    tok = jax.random.randint(jax.random.PRNGKey(1), (zr.U, 2, 32), 0,
                             cfg.vocab_size, jnp.int32)
    raw = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=-1)}
    batch = zr.shard_batch(raw)

    # real gradients, already in the (U, n_chunks, D_c) compressor layout
    g, losses = zr.grads_in_layout(master, batch)
    gr, lref = zr.reference_grads(chunked, raw)
    assert np.array_equal(np.asarray(g), np.asarray(gr)), "grads"
    assert np.array_equal(np.asarray(losses), np.asarray(lref)), "losses"

    # 3 chained real-gradient rounds stay bitwise vs the jitted oracle
    m, rc = master, chunked
    for t in range(3):
        m, st = zr.round_train(m, batch, t, key, 1e-4, 10.0, 0.05)
        rc, rst = zr.reference_round_train(rc, raw, t, key, 1e-4, 10.0,
                                           0.05)
        assert np.array_equal(np.asarray(m.master),
                              np.asarray(rc.master)), t
        # loss is telemetry, not round state: the mesh computes it as
        # psum/U, the oracle as mean-over-lax.map — different reduction
        # structures, so close-not-bitwise by contract
        np.testing.assert_allclose(float(st.loss), float(rst.loss),
                                   rtol=1e-5)
        assert np.isfinite(float(st.loss))
    assert all(np.isfinite(np.asarray(x)).all() for x in st.budget)

    # one-program multi-arm sweep == the oracle with the SAME scan/map
    # wrapping (parity is per program structure, DESIGN.md §16)
    A = 2
    arms = {"noise_var": jnp.array([1e-4, 1e-3], jnp.float32),
            "p_max": jnp.full((A,), 10.0, jnp.float32),
            "lr": jnp.array([0.05, 0.02], jnp.float32)}
    stacked = jnp.broadcast_to(chunked, (A,) + chunked.shape)
    ms = zr.shard_masters(stacked)
    m2, _ = zr.run_sweep(ms, batch, arms, 2, key=key)
    r2, _ = zr.reference_sweep(stacked, raw, arms, 2, key=key)
    assert np.array_equal(np.asarray(m2.master),
                          np.asarray(r2.master)), "sweep"
    print("OK")
""")


SCRIPT_OPT_STATE_PARITY = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine.zoo_train import build_zoo_train_round
    from repro.models.registry import build_model

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ob = OBCSAAConfig(chunk=256, measure=64, topk=16, biht_iters=3,
                      recon_alg="iht", spmd_topk=True, packed=True,
                      bisect_iters=16)
    cfg = get_smoke_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0,
                             cfg.vocab_size, jnp.int32)
    raw = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=-1)}

    def leaves_equal(a, b, tag):
        la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        assert len(la) == len(lb), tag
        for i, (x, y) in enumerate(zip(la, lb)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (tag, i)

    # sharded optimizer moments + per-worker EF residuals: a >=3-round
    # chain on the 4x2 mesh is bitwise vs the jitted oracle on EVERY
    # carry leaf (master, moments, adam's step counter, residual)
    for name, kw in (("momentum", dict(beta=0.9)), ("adam", {})):
        zr = build_zoo_train_round(model, mesh, ob, optimizer=name,
                                   opt_kwargs=kw, error_feedback=True)
        chunked = zr.chunk_params(params)
        batch = zr.shard_batch(raw)
        s = zr.shard_state(zr.init_state(chunked))
        r = zr.init_state(chunked)
        for t in range(3):
            s, st = zr.round_train(s, batch, t, key, 1e-4, 10.0, 0.05)
            r, rst = zr.reference_round_train(r, raw, t, key, 1e-4,
                                              10.0, 0.05)
            leaves_equal(s, r, (name, t))
            assert np.isfinite(float(st.loss)), (name, t)
        assert float(np.abs(np.asarray(s.residual)).sum()) > 0, name
        print(name + " chain parity OK", flush=True)

    # mid-chain checkpoint resume with non-trivial adam moments + EF
    # residuals: 4 rounds == 2 rounds -> save_state -> restore_state ->
    # 2 rounds, bit for bit on all carry leaves (zr is the adam round)
    s0 = zr.shard_state(zr.init_state(chunked))
    full, half = s0, s0
    for t in range(4):
        full, _ = zr.round_train(full, batch, t, key, 1e-4, 10.0, 0.05)
    for t in range(2):
        half, _ = zr.round_train(half, batch, t, key, 1e-4, 10.0, 0.05)
    with tempfile.TemporaryDirectory() as td:
        zr.save_state(td, 2, half, t_next=2)
        res, t0 = zr.restore_state(td)
        assert t0 == 2, t0
        for t in range(t0, 4):
            res, _ = zr.round_train(res, batch, t, key, 1e-4, 10.0, 0.05)
    leaves_equal(full, res, "chain resume")
    print("chain resume OK", flush=True)

    # mid-SWEEP resume: the one-program arms x rounds scan restarted
    # from a restored arm-stacked carry at t0=2 lands bitwise on the
    # uninterrupted 4-round sweep
    A = 2
    arms = {"noise_var": jnp.array([1e-4, 1e-3], jnp.float32),
            "p_max": jnp.full((A,), 10.0, jnp.float32),
            "lr": jnp.array([0.05, 0.02], jnp.float32)}
    states0 = zr.shard_state(zr.init_sweep_state(
        jnp.broadcast_to(chunked, (A,) + chunked.shape)), arms=A)
    full, _ = zr.run_sweep(states0, batch, arms, 4, key=key)
    half, _ = zr.run_sweep(states0, batch, arms, 2, key=key)
    with tempfile.TemporaryDirectory() as td:
        zr.save_state(td, 2, half, t_next=2)
        states2, t0 = zr.restore_state(td, arms=A)
        assert t0 == 2, t0
        resumed, _ = zr.run_sweep(states2, batch, arms, 2, key=key,
                                  t0=t0)
    leaves_equal(full, resumed, "sweep resume")
    print("OK")
""")


@pytest.mark.slow
def test_zoo_train_opt_state_ef_parity_8dev():
    """Tentpole gate (DESIGN.md §17): momentum/adam moments as sharded
    (n_chunks, D_c) carries and per-worker EF residuals as the
    (U, n_chunks, D_c) grads-layout carry stay bitwise vs the jitted
    single-device oracle over 3-round chains on the 4x2 mesh, and a
    checkpoint saved mid-chain and mid-sweep (moments + residuals +
    t_next) resumes bit for bit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_OPT_STATE_PARITY],
                       env=env, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout


@pytest.mark.slow
def test_zoo_train_sharded_bitwise_parity_8dev():
    """Real backward passes on the 4 workers x 2 model shards mesh ==
    single-device oracle, bit for bit: raw in-layout gradients, chained
    rounds, and the multi-arm sweep (DESIGN.md §16)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_TRAIN_PARITY],
                       env=env, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
