"""Smoke the multi-pod dry-run machinery end-to-end (subprocess: the
512-host-device XLA flag must not leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import repro.launch.dryrun as dr
res = dr.lower_combo("whisper-base", "decode_32k", multi_pod=False)
assert res["status"] == "ok", res
assert res["cost"]["flops"] > 0
assert res["collectives"]["total_count"] > 0
assert res["memory"]["temp_bytes"] is not None
# long_500k rule: full-attention arch is skipped with the documented reason
res2 = dr.lower_combo("whisper-base", "long_500k", multi_pod=False)
assert res2["status"] == "skipped" and "sub-quadratic" in res2["reason"]
print("DRYRUN_OK")
"""


@pytest.mark.slow
def test_dryrun_lower_compile_and_skip_rule():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-2000:]}"
    assert "DRYRUN_OK" in r.stdout
