"""Continuous scheduling service (repro.serve) + the plumbing it rides on
(DESIGN.md §15).

The load-bearing claims:
- ``step_fades`` chained T times is BITWISE the whole-trajectory
  ``generate_fades`` at every round, and the stepped process keeps the
  Rayleigh marginal / ρ^ℓ autocorrelation;
- the shared pow2 compaction utility (sched/compaction.py) buckets
  exactly as the pre-extraction ADMM loop did (the host-compacted and
  scan-safe solvers stay bitwise-identical per lane);
- dual warm-starting returns/accepts multipliers without changing β
  (bitwise), and both solvers are per-lane bitwise-invariant to batch
  composition — the two facts the serve cache rests on;
- at ``stale_threshold=0`` the served cache equals a cold full-fleet
  solve bitwise (with partial CSI reporting exercising real cache hits);
- the engine carries ν/λ next to prev-β: ``sched_warm_duals`` on is
  bitwise the off trajectory, and scan ≡ host with it on;
- the launch surface: ``repro.launch.serve`` is a deprecation shim over
  ``decode_demo``, and the service CLI runs.
"""
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import draw_cn, gauss_markov_step
from repro.sched import (AdmmDuals, BatchedProblem, ScenarioConfig,
                         SchedConfig, admm_solve_batched,
                         admm_solve_batched_jit, bucket, generate_fades,
                         greedy_solve_batched, init_fades, magnitudes,
                         pad_to_bucket, step_fades)
from repro.serve import (ServeConfig, TickStats, fresh_solve, ingest,
                         init_service, movement, run_ticks, tick)
from repro.theory.bounds import AnalysisConstants

U = 16
CONST = AnalysisConstants(rho1=200.0, G=1.0)


def _problem(g, k_weights=3000.0) -> BatchedProblem:
    h = jnp.maximum(jnp.abs(g).astype(jnp.float32), 1e-3)
    return BatchedProblem.from_arrays(h, k_weights, 10.0, 1e-4, D=50890,
                                      S=1000, kappa=1000, const=CONST)


def _serve_cfg(cells=96, **kw) -> ServeConfig:
    base = dict(scenario=ScenarioConfig(cells=cells, workers=U, corr=0.99),
                stale_threshold=0.0, update_frac=0.4)
    base.update(kw)
    return ServeConfig(**base)


# --- streaming scenario stepping ----------------------------------------------------

def test_step_fades_matches_trajectory_bitwise():
    """The tentpole refactor contract: chaining the incremental
    transition reproduces the whole-trajectory draw bitwise at EVERY
    round (same jitted executable on both paths)."""
    cfg = ScenarioConfig(rounds=24, cells=4, workers=8, corr=0.9)
    key = jax.random.PRNGKey(3)
    traj = np.asarray(generate_fades(cfg, key))
    st = init_fades(cfg, key)
    for t in range(cfg.rounds):
        assert np.array_equal(np.asarray(st.g), traj[t]), t
        assert int(st.t) == t
        if t < cfg.rounds - 1:
            st = step_fades(cfg, st)


def test_stepped_fades_keep_rayleigh_marginal_and_autocorr():
    """The test_sched.py statistical regression, on the stepped process:
    stationary CN(0, 1) marginal (E|g|² = 1, E|g| = √π/2) and lag-ℓ
    autocorrelation ρ^ℓ."""
    cfg = ScenarioConfig(rounds=400, cells=4, workers=64, corr=0.9)
    st = init_fades(cfg, jax.random.PRNGKey(1))
    gs = [st.g]
    for _ in range(cfg.rounds - 1):
        st = step_fades(cfg, st)
        gs.append(st.g)
    g = jnp.stack(gs)
    mag = jnp.abs(g)
    assert abs(float(jnp.mean(mag ** 2)) - 1.0) < 0.05
    assert abs(float(jnp.mean(mag)) - np.sqrt(np.pi) / 2) < 0.02
    gf = g.reshape(cfg.rounds, -1)
    for lag in (1, 3):
        ac = float(jnp.mean(jnp.real(gf[lag:] * jnp.conj(gf[:-lag]))))
        assert abs(ac - cfg.rho ** lag) < 0.05, lag


def test_magnitudes_clamps_and_scales():
    cfg = ScenarioConfig(cells=2, workers=8)
    st = init_fades(cfg, jax.random.PRNGKey(0))
    h = magnitudes(st)
    assert h.dtype == jnp.float32 and float(h.min()) >= cfg.h_min
    gain = 2.0 * jnp.ones((2, 8), jnp.float32)
    assert np.allclose(np.asarray(magnitudes(st.g, gain)),
                       np.maximum(np.abs(np.asarray(st.g)) * 2.0, cfg.h_min))


# --- shared pow2 compaction ---------------------------------------------------------

def test_bucket_and_pad_properties():
    assert bucket(1) == 8 and bucket(8) == 8 and bucket(9) == 16
    assert bucket(1000) == 1024 and bucket(3, min_bucket=2) == 4
    with pytest.raises(ValueError):
        bucket(0)
    idx = np.array([5, 9, 11])
    pad, valid = pad_to_bucket(idx)
    assert pad.shape == (8,) and valid.sum() == 3
    assert np.array_equal(pad[:3], idx) and (pad[3:] == 5).all()
    with pytest.raises(ValueError):
        pad_to_bucket(np.array([], np.int64))


def test_compacted_solver_matches_jit_bitwise():
    """The compaction-extraction refactor changes nothing: the
    host-compacted fleet solver and the scan-safe jit solver agree
    bitwise per lane — β, b_t, R_t, exit duals AND iteration counts —
    at a B that exercises several compaction retirements."""
    g = draw_cn(jax.random.PRNGKey(5), (48, U))
    prob = _problem(g)
    b1, t1, r1, i1 = admm_solve_batched(prob, return_duals=True)
    b2, t2, r2, i2 = admm_solve_batched_jit(prob, return_duals=True)
    for a, b in ((b1, b2), (t1, t2), (r1, r2), (i1.iters, i2.iters),
                 *zip(i1.duals, i2.duals)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --- dual warm-starting -------------------------------------------------------------

def test_warm_start_preserves_beta_bitwise():
    """Seeding the multipliers from a correlated earlier solve must not
    change the converged β (the primal re-initializes; serve-bench gates
    the same flag at larger B)."""
    k0, k1 = jax.random.split(jax.random.PRNGKey(2))
    g0 = draw_cn(k0, (64, U))
    _, _, _, info = admm_solve_batched(_problem(g0), return_duals=True)
    assert info.duals.nu.shape == (64, U)
    assert bool((info.duals.nu >= 0).all())          # eq. 37 prices
    assert info.iters.dtype == jnp.int32
    g1 = gauss_markov_step(g0, k1, 0.99)
    prob1 = _problem(g1)
    beta_c, bt_c, rt_c = admm_solve_batched(prob1)
    beta_w, bt_w, rt_w, _ = admm_solve_batched(prob1, duals=info.duals,
                                               return_duals=True)
    assert np.array_equal(np.asarray(beta_c), np.asarray(beta_w))
    assert np.array_equal(np.asarray(bt_c), np.asarray(bt_w))
    assert np.array_equal(np.asarray(rt_c), np.asarray(rt_w))


def test_solvers_batch_composition_invariant():
    """Per-lane results must not depend on which other lanes share the
    batch — the fact that makes bucketed incremental solves equal a
    one-shot fleet solve (the serve cache-parity foundation)."""
    rng = np.random.default_rng(7)
    g = draw_cn(jax.random.PRNGKey(7), (64, U))
    full_a = np.asarray(admm_solve_batched(_problem(g))[0])
    full_g = np.asarray(greedy_solve_batched(_problem(g))[0])
    for B in (8, 16):
        idx = rng.choice(64, B, replace=False)
        sub = _problem(np.asarray(g)[idx])
        assert np.array_equal(np.asarray(admm_solve_batched(sub)[0]),
                              full_a[idx])
        assert np.array_equal(np.asarray(greedy_solve_batched(sub)[0]),
                              full_g[idx])


# --- the service loop ---------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["admm_batched", "greedy_batched"])
def test_serve_cache_parity_at_threshold_zero(scheduler):
    """Acceptance flag (1): at threshold 0 with partial CSI reporting,
    the served cache — a patchwork of solves from different ticks and
    bucket sizes — equals a cold full-fleet solve bitwise."""
    cfg = _serve_cfg(scheduler=scheduler)
    st = init_service(cfg, jax.random.PRNGKey(0))
    st, stats, _ = run_ticks(cfg, st, 5)
    # partial reporting produced real cache hits after the cold tick
    assert any(s.hit_rate > 0 for s in stats[1:])
    beta, b_t, rt = fresh_solve(cfg, st)
    assert np.array_equal(np.asarray(beta), np.asarray(st.beta))
    assert np.array_equal(np.asarray(b_t), np.asarray(st.b_t))
    assert np.array_equal(np.asarray(rt), np.asarray(st.rt))


def test_serve_hit_rate_accounting():
    """Tick 0 is all-dirty (cold cache); afterwards only reporting cells
    can be dirty, and the hit rate is 1 − dirty/cells."""
    cfg = _serve_cfg(cells=64)
    st = init_service(cfg, jax.random.PRNGKey(4))
    st, stats, _ = run_ticks(cfg, st, 4)
    assert stats[0].n_dirty == 64 and stats[0].hit_rate == 0.0
    for s in stats[1:]:
        assert s.n_dirty <= s.n_reported
        assert s.hit_rate == 1.0 - s.n_dirty / 64
        assert s.n_solved >= s.n_dirty       # pow2 pad lanes included
        assert isinstance(s, TickStats)


def test_serve_threshold_freezes_cache():
    """An effectively infinite staleness threshold never re-solves after
    the cold tick — the cache is served unchanged."""
    cfg = _serve_cfg(cells=32, stale_threshold=1e9, update_frac=1.0)
    st = init_service(cfg, jax.random.PRNGKey(0))
    st, stats0, _ = run_ticks(cfg, st, 1)
    beta0 = np.asarray(st.beta)
    st, stats, _ = run_ticks(cfg, st, 3)
    assert all(s.n_dirty == 0 and s.n_solved == 0 for s in stats)
    assert np.array_equal(np.asarray(st.beta), beta0)


def test_serve_ingest_marks_dirty():
    """An out-of-band CSI push re-solves exactly the pushed cells on the
    next tick (update_frac=0: no other reports compete)."""
    cfg = _serve_cfg(cells=32, update_frac=0.0)
    st = init_service(cfg, jax.random.PRNGKey(1))
    st, _, _ = run_ticks(cfg, st, 2)                # cold solve, then idle
    h_new = np.asarray(st.h_seen)[[3, 7]] * 1.5
    st = ingest(st, [3, 7], h_new)
    assert set(np.flatnonzero(movement(cfg, st) > 0)) == {3, 7}
    st, stats = tick(cfg, st)
    assert stats.n_dirty == 2 and stats.n_reported == 0
    assert np.array_equal(np.asarray(st.h_solved)[[3, 7]], h_new)


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(scheduler="enum")
    with pytest.raises(ValueError):
        ServeConfig(update_frac=1.5)
    with pytest.raises(ValueError):
        ServeConfig(stale_threshold=-0.1)
    assert ServeConfig().warm
    assert not ServeConfig(scheduler="greedy_batched").warm


# --- engine carries ν/λ next to prev-β ----------------------------------------------

@pytest.fixture(scope="module")
def fl_task():
    """Tiny linear-regression FL task (4 workers) for the engine runs."""
    rng = np.random.default_rng(0)
    workers, D, n = 4, 40, 16
    x = rng.normal(size=(workers, n, D)).astype(np.float32)
    w_true = rng.normal(size=(D,)).astype(np.float32)
    y = (x @ w_true
         + 0.1 * rng.normal(size=(workers, n)).astype(np.float32))
    wd = {"x": jnp.asarray(x), "y": jnp.asarray(y.astype(np.float32))}
    params0 = {"w": jnp.zeros((D,), jnp.float32)}

    def loss_fn(p, d):
        return jnp.mean((d["x"] @ p["w"] - d["y"]) ** 2)

    return wd, params0, loss_fn, np.full(workers, float(n))


def _fl_cfg(**kw):
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine import FLConfig
    base = dict(aggregator="obcsaa", scheduler="admm_batched", rounds=6,
                seed=0, channel_rho=0.9, const=CONST,
                obcsaa=OBCSAAConfig(chunk=40, measure=20, topk=4))
    base.update(kw)
    return FLConfig(**base)


def _run_fl(task, cfg):
    from repro.fl import FederatedTrainer
    wd, params0, loss_fn, kw = task
    tr = FederatedTrainer(cfg, loss_fn, params0, wd, kw)
    tr.run(cfg.rounds)
    return tr


def test_engine_warm_duals_bitwise_neutral(fl_task):
    """Acceptance flag (2) at the engine layer: carrying ν/λ in the scan
    state and warm-starting every round's P2 leaves the training
    trajectory bitwise-unchanged (β is bitwise-stable under dual warm
    starts), and the carry actually holds the duals."""
    off = _run_fl(fl_task, _fl_cfg(sched_warm_duals=False))
    on = _run_fl(fl_task, _fl_cfg(sched_warm_duals=True))
    assert off._state.sched_duals is None
    assert isinstance(on._state.sched_duals, AdmmDuals)
    assert on._state.sched_duals.nu.shape == (4,)
    assert np.array_equal(np.asarray(off.params["w"]),
                          np.asarray(on.params["w"]))


def test_engine_warm_duals_scan_equals_host(fl_task):
    """scan ≡ host parity survives the dual carry: both paths thread the
    same (β, b_t, duals) triple through the same round body."""
    scan = _run_fl(fl_task, _fl_cfg(sched_warm_duals=True, mode="scan"))
    host = _run_fl(fl_task, _fl_cfg(sched_warm_duals=True, mode="host"))
    assert scan._mode == "scan" and host._mode == "host"
    assert np.array_equal(np.asarray(scan.params["w"]),
                          np.asarray(host.params["w"]))
    for a, b in zip(scan._state.sched_duals, host._state.sched_duals):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --- launch surface -----------------------------------------------------------------

def test_launch_serve_shim_deprecates():
    sys.modules.pop("repro.launch.serve", None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.launch.serve as shim
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    from repro.launch.decode_demo import main as demo_main
    assert shim.main is demo_main


def test_serve_cli_smoke(capsys):
    from repro.serve.cli import main
    rc = main(["--cells", "32", "--workers", "8", "--ticks", "2",
               "--threshold", "0.0"])
    out = capsys.readouterr().out
    assert rc == 0 and "SLO:" in out and "hit_rate" in out
