"""Deterministic stand-in for the slice of the hypothesis API used by
test_property.py, so property tests still execute in containers where
hypothesis isn't installed (this repo can't add dependencies). Real
hypothesis is preferred whenever importable — see the guarded import in
test_property.py.

Each ``@given`` test runs ``max_examples`` times with arguments drawn from
a PRNG seeded by (test name, example index): deterministic across runs and
interpreters, no shrinking, failures report the falsifying example.
"""
from __future__ import annotations


import random
import zlib


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def map(self, f):
        return _Strategy(lambda rng: f(self._sample(rng)))


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


class settings:
    _profiles: dict = {}
    _current: dict = {"max_examples": 25}

    def __init__(self, **kwargs):
        self.kwargs = kwargs

    @classmethod
    def register_profile(cls, name, max_examples=25, **_ignored):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name):
        cls._current = dict(cls._profiles.get(name, cls._current))


def given(*strats):
    def deco(f):
        def wrapper():
            n = settings._current["max_examples"]
            base = zlib.crc32(f.__name__.encode())
            for i in range(n):
                rng = random.Random(base * 1_000_003 + i)
                args = [s._sample(rng) for s in strats]
                try:
                    f(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (run {i}): {args!r}") from e
        # no functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and demand fixtures for the drawn arguments
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper
    return deco
