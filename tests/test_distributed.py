"""Distributed OBCSAA path: the shard_map (partial-manual) aggregation must
equal the centralized simulation, and the mean/obcsaa train steps must lower
and run on a multi-device host mesh. Runs in a subprocess so the 8-device
XLA flag never leaks into other tests."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.obcsaa import OBCSAAConfig, simulate_round, shardmap_aggregate
    from repro.core import channel as chan

    U, D = 4, 2048
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = OBCSAAConfig(chunk=1024, measure=256, topk=32, biht_iters=10)
    key = jax.random.PRNGKey(0)
    grads = jax.random.normal(key, (U, D))
    kw = jnp.ones(()); beta = jnp.ones((U,)); bt = jnp.float32(1.0)
    nkey = jax.random.PRNGKey(7)

    # centralized reference (workers equally weighted, unit channels)
    ghat_sim, _ = simulate_round(cfg, grads, jnp.ones((U,)), beta, bt,
                                 jnp.ones((U,)), nkey)

    # distributed: each data shard holds one worker's gradient
    def per_worker(g, beta_all, bt, nkey):
        widx = jax.lax.axis_index(("data",))
        ghat = shardmap_aggregate(cfg, g[0], ("data",), k_weight=jnp.float32(1.0),
                                  beta_i=beta_all[widx], b_t=bt,
                                  n_workers=U, noise_key=nkey)
        return ghat

    f = jax.shard_map(per_worker, mesh=mesh, axis_names={"data"},
                      in_specs=(P("data"), P(), P(), P()), out_specs=P(),
                      check_vma=False)
    with jax.set_mesh(mesh):
        ghat_dist = jax.jit(f)(grads, beta, bt, nkey)
    err = float(jnp.max(jnp.abs(ghat_dist[:D] - ghat_sim)))
    rel = err / (float(jnp.max(jnp.abs(ghat_sim))) + 1e-12)
    print("MAXERR", err, "REL", rel)
    assert rel < 5e-2, (err, rel)

    # train steps lower + run on the host mesh (both aggregations)
    from repro.configs import TrainConfig, get_smoke_config
    from repro.launch import steps as steps_lib
    from repro.models.registry import build_model
    from repro.data import token_stream

    cfg2 = get_smoke_config("gemma2-2b")
    model = build_model(cfg2)
    for agg in ("mean", "obcsaa"):
        tcfg = TrainConfig(aggregation=agg, cs_chunk=512, cs_measure=128,
                           cs_topk=32, biht_iters=3, learning_rate=0.01)
        with jax.set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            opt = steps_lib.make_optimizer(tcfg)
            ostate = opt.init(params)
            step = jax.jit(steps_lib.make_train_step(model, tcfg, mesh))
            toks, tgts = token_stream(8, 32, cfg2.vocab_size)
            batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
            losses = []
            for t in range(3):
                ctx = steps_lib.default_round_ctx(mesh, seed=t)
                params, ostate, m = step(params, ostate, batch, ctx)
                losses.append(float(m["loss"]))
            print("AGG", agg, losses)
            assert losses[-1] < losses[0], (agg, losses)
    print("OK")
""")


@pytest.mark.slow
def test_distributed_equivalence_and_train_steps():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout


SCRIPT_PACKED_MAC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import psum_bits_mac
    from repro.kernels.sign import pack_signs, unpack_signs

    # 8 workers, one per device: the int32 packed-word MAC psum must equal
    # the f32 einsum superposition of the unpacked +-1 symbols bit for bit
    # (uniform power-of-two scale K*b_t => every partial sum is exact).
    U, n, S = 8, 3, 256
    mesh = jax.make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(0)
    proj = jax.random.normal(key, (U, n, S))
    packed = pack_signs(proj)                       # (U, n, S//32) uint32
    symbols = unpack_signs(packed)                  # (U, n, S) +-1 f32
    beta = (jax.random.uniform(jax.random.PRNGKey(1), (U,)) > 0.3)
    beta = beta.astype(jnp.float32)
    scale = jnp.float32(0.5)                        # K*b_t, power of two

    y_ref = jnp.einsum("u,uns->ns", beta * scale, symbols)

    def per_worker(pk, beta_all):
        widx = jax.lax.axis_index("data")
        s_int = psum_bits_mac(pk[0], ("data",), beta_i=beta_all[widx])
        return s_int.astype(jnp.float32) * scale

    f = jax.shard_map(per_worker, mesh=mesh, axis_names={"data"},
                      in_specs=(P("data"), P()), out_specs=P(),
                      check_vma=False)
    with jax.set_mesh(mesh):
        y_mac = jax.jit(f)(packed, beta)
    assert y_mac.shape == y_ref.shape, (y_mac.shape, y_ref.shape)
    assert bool(jnp.all(y_mac == y_ref)), "packed MAC psum != f32 einsum"
    print("OK")
""")


SCRIPT_LARGE_D_UPLINK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.obcsaa import OBCSAAConfig, compress_chunks, shardmap_compress

    # zoo-scale packed uplink (DESIGN.md §14): full shardmap_compress ->
    # psum_bits_mac pipeline at D = 4.19M on the 8-worker mesh must equal
    # the single-device f32 symbol reference bit for bit. K*b_t = 0.5 is a
    # power of two, so every scaled int32 MAC value is exactly
    # representable in f32.
    U, CH, S = 8, 8192, 256
    D = 512 * CH
    cfg = OBCSAAConfig(chunk=CH, measure=S, topk=64, packed=True,
                       spmd_topk=True, bisect_iters=20)
    mesh = jax.make_mesh((8,), ("data",))
    grads = jnp.stack([
        0.1 * jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(0), u),
                                (D,), jnp.float32) for u in range(U)])
    beta = (jax.random.uniform(jax.random.PRNGKey(1), (U,)) > 0.25)
    beta = beta.astype(jnp.float32)
    bt = jnp.float32(0.5)

    def per_worker(g, beta_all):
        widx = jax.lax.axis_index("data")
        return shardmap_compress(cfg, g[0], ("data",),
                                 k_weight=jnp.float32(1.0),
                                 beta_i=beta_all[widx], b_t=bt)

    f = jax.shard_map(per_worker, mesh=mesh, axis_names={"data"},
                      in_specs=(P("data"), P()), out_specs=(P(), P(), P()),
                      check_vma=False)
    with jax.set_mesh(mesh):
        y, ksum, mag_sum = jax.jit(f)(grads, beta)

    # single-device f32 reference: same compression, f32 +-1 symbols,
    # plain weighted sums over the worker axis
    ref_cfg = dataclasses.replace(cfg, packed=False)

    @jax.jit
    def reference(grads, beta):
        signs, mags = jax.vmap(
            lambda g: compress_chunks(ref_cfg, g, None))(grads)
        y = jnp.einsum("u,ucs->cs", beta * bt, signs)
        return y, jnp.sum(beta), jnp.einsum("u,uc->c", beta, mags)

    y_ref, ksum_ref, mag_ref = reference(grads, beta)
    assert y.shape == (D // CH, S)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref)), "y"
    assert np.array_equal(np.asarray(ksum), np.asarray(ksum_ref)), "ksum"
    assert np.array_equal(np.asarray(mag_sum), np.asarray(mag_ref)), "mags"
    print("NNZROWS", int(jnp.sum(jnp.any(y != 0, axis=1))))
    print("OK")
""")


@pytest.mark.slow
def test_packed_uplink_large_d_bitwise_vs_single_device():
    """Satellite of the zoo PR: the packed compress+MAC uplink at D=4.19M
    (the ≥1B bench wire path, scaled to CI) on the 8-device mesh is
    bitwise equal to the single-device f32 symbol reference."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_LARGE_D_UPLINK],
                       env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout


@pytest.mark.slow
def test_packed_mac_psum_matches_einsum_on_mesh():
    """Worker-axis popcount-style MAC (DESIGN.md §13): int32 psum of
    packed sign words == the f32 symbol superposition, bitwise."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_PACKED_MAC], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
