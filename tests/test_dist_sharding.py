"""repro.dist sharding subsystem: best_spec / infer_param_sharding
properties on 1-device and 8-device CPU meshes, constrain's no-op
guarantees, and the worker-axis MAC equivalence — ``shardmap_compress``'s
psum over the worker axes must reproduce ``simulate_round``'s stacked
einsum superposition bit-for-bit (the over-the-air sum of ±w symbols is
exact integer arithmetic in float32).

Multi-device parts run in a subprocess so the 8-device XLA flag never
leaks into this (1-device) test process — same pattern as
test_distributed.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import collectives
from repro.dist.sharding import best_spec, constrain, infer_param_sharding
from repro.models.mlp_mnist import init_mlp_mnist

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --- 1-device mesh ---------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_best_spec_signature_and_hint_priority(mesh1):
    # exact call shape used by launch/steps.py:batch_pspecs and dryrun.py
    spec = best_spec((8, 16), ["data", None], mesh1)
    assert isinstance(spec, P)
    assert spec == P("data", None)
    # first divisible candidate in the hint list wins
    assert best_spec((8,), [["model", "data"]], mesh1) == P("model")


def test_best_spec_replication_fallback(mesh1):
    # no hint, or hint None -> replicated dims
    assert best_spec((4, 4), [None, None], mesh1) == P(None, None)
    # unknown axis names are skipped, not errors
    assert best_spec((4,), ["expert"], mesh1) == P(None)


def test_infer_param_sharding_1device(mesh1):
    params = init_mlp_mnist(jax.random.PRNGKey(0))
    sh = infer_param_sharding(params, mesh1)
    leaves = jax.tree_util.tree_leaves(sh)
    assert all(isinstance(s, NamedSharding) for s in leaves)
    # size-1 model axis shards trivially; placing params must round-trip
    placed = jax.device_put(params, sh)
    np.testing.assert_array_equal(np.asarray(placed["w1"]),
                                  np.asarray(params["w1"]))


def test_constrain_noop_without_mesh():
    x = jnp.arange(12.0).reshape(3, 4)
    y = constrain(x, ("data", "model"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_constrain_inside_jit_under_mesh(mesh1):
    x = jnp.arange(16.0).reshape(4, 4)

    @jax.jit
    def f(x):
        return constrain(x, ("data", None)) * 2

    with jax.set_mesh(mesh1):
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x) * 2)


def test_collectives_no_axes_identity():
    x = jnp.arange(4.0)
    np.testing.assert_array_equal(np.asarray(collectives.psum(x, ())),
                                  np.asarray(x))
    assert int(collectives.axis_index(())) == 0
    assert collectives.axis_size(()) == 1
    assert collectives.norm_axes("data") == ("data",)
    assert collectives.norm_axes(None) == ()


# --- 8-device mesh (subprocess) ---------------------------------------------------

PROP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import best_spec, infer_param_sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # divisibility: dim 6 is not divisible by data=4 -> next candidate/repl
    assert best_spec((6, 8), [["data", "model"], None], mesh) == P("model", None)
    assert best_spec((5, 7), [["data", "model"], None], mesh) == P(None, None)
    # hint priority: both divide, first named wins
    assert best_spec((8, 8), [["model", "data"], None], mesh) == P("model", None)
    # an axis is used at most once across dims
    assert best_spec((8, 8), ["data", "data"], mesh) == P("data", None)
    # "data" hint widens to ("pod", "data") on the 3-axis production mesh
    from repro.launch.mesh import worker_axes
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 2, "model": 2}
    assert best_spec((8, 4), ["data", None], FakeMesh()) == P(("pod", "data"),
                                                             None)
    # 3-axis worker-axes definition agrees
    assert worker_axes(FakeMesh()) == ("pod", "data")

    # infer_param_sharding: MNIST-MLP pytree (model=2)
    from repro.models.mlp_mnist import init_mlp_mnist
    params = init_mlp_mnist(jax.random.PRNGKey(0))
    sh = infer_param_sharding(params, mesh)
    assert sh["w1"].spec == P("model", None)     # largest dim 784 % 2 == 0
    assert sh["b2"].spec == P("model")           # 10 % 2 == 0
    placed = jax.device_put(params, sh)
    for k in params:
        np.testing.assert_array_equal(np.asarray(placed[k]),
                                      np.asarray(params[k]))

    # transformer smoke-config param AND optimizer-state pytrees place
    # without error and keep worker axes replicated
    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.optim.optimizers import adam
    model = build_model(get_smoke_config("gemma2-2b"))
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    psh = infer_param_sharding(pshapes, mesh)
    oshapes = jax.eval_shape(adam().init, pshapes)
    osh = infer_param_sharding(oshapes, mesh)
    for tree, shtree in ((pshapes, psh), (oshapes, osh)):
        for leaf, s in zip(jax.tree_util.tree_leaves(tree),
                           jax.tree_util.tree_leaves(shtree)):
            assert isinstance(s, NamedSharding)
            assert "data" not in jax.tree_util.tree_leaves(
                [list(p) if isinstance(p, tuple) else [p] for p in s.spec])
            for dim, p in zip(leaf.shape, s.spec):
                if p is not None:
                    assert dim % mesh.shape["model"] == 0
    print("PROPS_OK")
""")


MAC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.obcsaa import (OBCSAAConfig, compress_chunks,
                                   shardmap_aggregate, shardmap_compress,
                                   simulate_round)
    from repro.launch.mesh import make_host_mesh, num_workers, worker_axes

    mesh = make_host_mesh()
    waxes = worker_axes(mesh)
    U = num_workers(mesh)
    assert U == 8
    D = 2048
    cfg = OBCSAAConfig(chunk=512, measure=128, topk=24, biht_iters=8)
    grads = jax.random.normal(jax.random.PRNGKey(3), (U, D))
    beta = jnp.ones((U,)); bt = jnp.float32(1.0)
    nkey = jax.random.PRNGKey(11)

    # reference MAC: the stacked einsum superposition from simulate_round
    phi = cfg.phi()
    signs, mags = jax.vmap(lambda g: compress_chunks(cfg, g, phi))(grads)
    w = (jnp.ones((U,)) * beta * bt).astype(signs.dtype)
    y_ref = jnp.einsum("u,ucs->cs", w, signs)             # eq. (12), pre-noise
    ksum_ref = jnp.sum(jnp.ones((U,)) * beta)

    def per_worker(g, beta_all, bt):
        widx = jax.lax.axis_index(waxes)
        return shardmap_compress(cfg, g[0], waxes, k_weight=jnp.float32(1.0),
                                 beta_i=beta_all[widx], b_t=bt)

    f = jax.shard_map(per_worker, mesh=mesh, axis_names=set(waxes),
                      in_specs=(P("data"), P(), P()), out_specs=(P(), P(), P()),
                      check_vma=False)
    with jax.set_mesh(mesh):
        y, ksum, mag_sum = jax.jit(f)(grads, beta, bt)

    # the over-the-air sum of +-1 symbols is exact integer float arithmetic:
    # psum must match the einsum bit for bit
    assert np.array_equal(np.asarray(y), np.asarray(y_ref)), (
        np.abs(np.asarray(y) - np.asarray(y_ref)).max())
    assert float(ksum) == float(ksum_ref)
    mag_ref = jnp.einsum("u,uc->c", (jnp.ones((U,)) * beta).astype(mags.dtype),
                         mags)
    np.testing.assert_allclose(np.asarray(mag_sum), np.asarray(mag_ref),
                               rtol=1e-6)

    # end-to-end: distributed aggregate tracks the centralized simulation
    # for the same PRNG channel draw
    ghat_sim, _ = simulate_round(cfg, grads, jnp.ones((U,)), beta, bt,
                                 jnp.ones((U,)), nkey)
    def agg(g, beta_all, bt, nkey):
        widx = jax.lax.axis_index(waxes)
        return shardmap_aggregate(cfg, g[0], waxes, k_weight=jnp.float32(1.0),
                                  beta_i=beta_all[widx], b_t=bt, n_workers=U,
                                  noise_key=nkey)
    fa = jax.shard_map(agg, mesh=mesh, axis_names=set(waxes),
                       in_specs=(P("data"), P(), P(), P()), out_specs=P(),
                       check_vma=False)
    with jax.set_mesh(mesh):
        ghat = jax.jit(fa)(grads, beta, bt, nkey)
    np.testing.assert_allclose(np.asarray(ghat[:D]), np.asarray(ghat_sim),
                               rtol=1e-4, atol=1e-6)
    print("MAC_OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)


@pytest.mark.slow
def test_sharding_properties_8device():
    r = _run(PROP_SCRIPT)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "PROPS_OK" in r.stdout


@pytest.mark.slow
def test_worker_axis_mac_matches_simulation_bitwise():
    r = _run(MAC_SCRIPT)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "MAC_OK" in r.stdout
