"""OBCSAA invariants: quantization, power control (eq. 10-11), RIP,
Lemma 1 bound vs empirical error, magnitude tracking, comm stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.theory import AnalysisConstants, lemma1_error_bound
from repro.core.measurement import (make_phi, reconstruction_constant,
                                    rip_constant_estimate)
from repro.core.obcsaa import OBCSAAConfig, comm_stats, compress_chunks, simulate_round
from repro.core.power_control import feasible, max_bt, power_factors, tx_power
from repro.core.quantize import pack_bits, sign_pm1, unpack_bits
from repro.core.sparsify import topk_sparsify

CFG = OBCSAAConfig(chunk=1024, measure=512, topk=64, biht_iters=25)


def _worker_grads(U=6, D=2048, seed=0):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    base = jnp.zeros((D,)).at[jax.random.choice(k0, D, (64,),
                                                replace=False)].set(
        jax.random.normal(k1, (64,)))
    return base[None] + 0.05 * jax.random.normal(k2, (U, D))


def test_compression_symbols_are_pm1():
    g = _worker_grads()[0]
    signs, mags = compress_chunks(CFG, jnp.pad(g, (0, 0)))
    assert bool(jnp.all(jnp.abs(signs) == 1.0))
    assert signs.shape == (2048 // CFG.chunk, CFG.measure)
    assert bool(jnp.all(mags > 0))


def test_power_constraint_gradient_independent():
    """Eq. 11: transmit power depends only on (β, K, b, h) — never on g."""
    U = 5
    h = jnp.asarray([0.3, 1.2, 0.7, 2.0, 0.05])
    kw = jnp.full((U,), 3000.0)
    beta = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0])
    bt = max_bt(beta, kw, h, 10.0)
    assert bool(feasible(beta, kw, bt, h, 10.0))
    # tightest worker hits the boundary exactly
    p = tx_power(beta, kw, bt, h)
    assert np.isclose(float(jnp.max(p)), 10.0, rtol=1e-5)
    # any larger b_t violates
    assert not bool(feasible(beta, kw, bt * 1.01, h, 10.0))


def test_channel_inversion():
    """p_i h_i = β_i K_i b_t: fading is perfectly pre-compensated (eq. 12)."""
    h = jnp.asarray([0.5, 2.0])
    kw = jnp.asarray([10.0, 20.0])
    beta = jnp.ones((2,))
    p = power_factors(beta, kw, 0.3, h)
    np.testing.assert_allclose(np.asarray(p * h), np.asarray(beta * kw * 0.3),
                               rtol=1e-6)


def test_rip_constant_reasonable():
    phi = make_phi(0, 512, 1024)
    delta = float(rip_constant_estimate(phi, sparsity=32, n_trials=32))
    assert 0.0 < delta < 0.6


def test_reconstruction_constant_monotone():
    cs = [reconstruction_constant(d) for d in (0.05, 0.15, 0.3)]
    assert cs[0] < cs[1] < cs[2]
    with pytest.raises(ValueError):
        reconstruction_constant(0.9)  # violates delta <= sqrt(2)-1 regime


def test_lemma1_bound_dominates_empirical_error():
    """Empirical ||ĝ − ḡ||² should sit below the Lemma 1 bound with the
    constants instantiated from the actual gradients."""
    U, D = 6, 2048
    grads = _worker_grads(U, D)
    kw = jnp.ones((U,))
    beta = jnp.ones((U,))
    bt = 1.0
    ghat, _ = simulate_round(CFG, grads, kw, beta, bt, jnp.ones((U,)),
                             jax.random.PRNGKey(1))
    gbar = jnp.mean(grads, axis=0)
    err = float(jnp.sum((ghat - gbar) ** 2))
    G = float(jnp.max(jnp.linalg.norm(grads, axis=-1)))
    const = AnalysisConstants(G=G, delta=0.3)
    bound = float(lemma1_error_bound(
        const, D=D, S=CFG.measure * 2, kappa=CFG.topk * 2, beta=beta,
        k_weights=kw, b_t=bt, noise_var=CFG.noise_var))
    assert err < bound


def test_magnitude_tracking_restores_scale():
    U, D = 6, 2048
    grads = _worker_grads(U, D)
    kw, beta = jnp.ones((U,)), jnp.ones((U,))
    ghat, _ = simulate_round(CFG, grads, kw, beta, 1.0, jnp.ones((U,)),
                             jax.random.PRNGKey(2))
    sp = jax.vmap(lambda g: topk_sparsify(g, CFG.topk * 2)[0])(grads)
    target_norm = float(jnp.linalg.norm(jnp.mean(sp, axis=0)))
    got = float(jnp.linalg.norm(ghat))
    assert 0.5 * target_norm < got < 2.0 * target_norm


def test_obcsaa_beats_no_aggregation_direction():
    U, D = 8, 2048
    grads = _worker_grads(U, D, seed=3)
    ghat, _ = simulate_round(CFG, grads, jnp.ones((U,)), jnp.ones((U,)), 1.0,
                             jnp.ones((U,)), jax.random.PRNGKey(3))
    gbar = jnp.mean(grads, axis=0)
    cos = float(jnp.dot(ghat, gbar)
                / (jnp.linalg.norm(ghat) * jnp.linalg.norm(gbar)))
    assert cos > 0.65


def test_pack_unpack_roundtrip():
    signs = sign_pm1(jax.random.normal(jax.random.PRNGKey(0), (1024,)))
    packed = pack_bits(signs)
    assert packed.size == 128
    np.testing.assert_array_equal(np.asarray(unpack_bits(packed, 1024)),
                                  np.asarray(signs))


def test_comm_stats():
    st = comm_stats(OBCSAAConfig(chunk=4096, measure=1024, topk=400), 50890)
    assert st["n_chunks"] == 13
    assert st["symbols_per_round"] == 13 * 1024 + 13
    assert st["compression_ratio"] > 3.8


def test_worker_scheduling_zeroes_unscheduled():
    """β_i = 0 workers contribute nothing (their p_i = 0)."""
    U, D = 4, 1024
    grads = _worker_grads(U, D, seed=4)
    kw = jnp.ones((U,))
    beta_all = jnp.ones((U,))
    beta_one = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    # make worker 0's gradient wildly different
    grads = grads.at[0].mul(-1.0)
    g_all, _ = simulate_round(CFG, grads, kw, beta_all, 1.0, jnp.ones((U,)),
                              jax.random.PRNGKey(5))
    g_one, _ = simulate_round(CFG, grads, kw, beta_one, 1.0, jnp.ones((U,)),
                              jax.random.PRNGKey(5))
    sp0 = topk_sparsify(grads[0], CFG.topk * 2)[0]

    def cos(a, b):
        return float(jnp.dot(a, b)
                     / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-12))

    # only worker 0 was heard: its direction dominates the β=e_0 round and
    # is much weaker in the all-scheduled round (worker 0's gradient is the
    # negation of the shared signal, so the average cancels it)
    assert cos(g_one, sp0) > 0.5
    assert cos(g_one, sp0) > cos(g_all, sp0) + 0.3
    assert not np.allclose(np.asarray(g_all), np.asarray(g_one))
