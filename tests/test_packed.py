"""Bit-packed 1-bit uplink path (DESIGN.md §13).

The packed codec (kernels/sign.py: 32 signs per uint32 word, LSB-first,
bit = 1 ⇔ projection ≥ 0 ⇔ +1) must be bit-for-bit equal to the f32 ±1
path through the whole pipeline — quantize → measure → MAC → decode —
because pack applies the SAME ``x >= 0`` predicate as the sign epilogue
and unpack reproduces the identical ±1.0 floats. These tests pin that
contract, the sign(0) := +1 convention at every call site, the explicit
shape-validation errors, and the 32x wire accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.obcsaa import OBCSAAConfig, comm_stats, compress_chunks, \
    simulate_round
from repro.core import quantize
from repro.decode.fused import fused_biht_packed
from repro.kernels import backproject as bp
from repro.kernels import cs_project
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.sign import (PACK, pack_bool, pack_signs, packed_width,
                                sign_pm1, unpack_bits, unpack_signs)


def _proj_inputs(seed, n, s, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    phi = jax.random.normal(k1, (s, d)) / jnp.sqrt(jnp.float32(s))
    chunks = jax.random.normal(k2, (n, d))
    return phi, chunks


# --- codec round trip ---------------------------------------------------------------

class TestCodec:
    def test_pack_unpack_roundtrip_property(self):
        """unpack(pack(x)) == sign(x) elementwise for random floats,
        including exact zeros and negative zeros."""
        rng = np.random.default_rng(0)
        x = rng.standard_normal((7, 4, 96)).astype(np.float32)
        x[rng.random(x.shape) < 0.1] = 0.0
        x[rng.random(x.shape) < 0.05] = -0.0
        x = jnp.asarray(x)
        packed = pack_signs(x)
        assert packed.dtype == jnp.uint32
        assert packed.shape == x.shape[:-1] + (x.shape[-1] // PACK,)
        out = unpack_signs(packed)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(sign_pm1(x)))
        # bool plane round trip
        bits = x >= 0
        np.testing.assert_array_equal(
            np.asarray(unpack_bits(pack_bool(bits), jnp.int32)),
            np.asarray(bits).astype(np.int32))

    def test_pack_is_lsb_first(self):
        """Word j covers lanes [32j, 32j+32), bit b = lane 32j+b."""
        x = -jnp.ones((64,))
        x = x.at[0].set(1.0).at[33].set(1.0)
        w = np.asarray(pack_signs(x))
        assert w[0] == 1 and w[1] == 2

    def test_packed_width_requires_multiple_of_32(self):
        assert packed_width(96) == 3
        with pytest.raises(ValueError, match="32"):
            packed_width(100)
        with pytest.raises(ValueError, match="32"):
            pack_signs(jnp.ones((4, 100)))


# --- sign(0) convention --------------------------------------------------------------

class TestSignZeroConvention:
    """sign(0) := +1 (eq. 11 needs ±1 symbols — a 0 would transmit
    nothing) from ONE shared helper at every call site."""

    def test_sign_pm1_exact_zero_is_plus_one(self):
        x = jnp.asarray([-1.5, -0.0, 0.0, 2.5, jnp.finfo(jnp.float32).tiny])
        np.testing.assert_array_equal(np.asarray(sign_pm1(x)),
                                      [-1.0, 1.0, 1.0, 1.0, 1.0])

    def test_all_call_sites_share_the_convention(self):
        """A zero gradient projects to exactly 0 everywhere: the kernel
        epilogue, the einsum reference, the quantize helper and the packed
        codec must all emit +1 for it."""
        phi, _ = _proj_inputs(0, 4, 64, 256)
        zeros = jnp.zeros((4, 256))
        expect = np.ones((4, 64), np.float32)
        np.testing.assert_array_equal(
            np.asarray(kops.cs_project_sign(phi, zeros)), expect)
        np.testing.assert_array_equal(
            np.asarray(ref.cs_project_sign_ref(phi, zeros)), expect)
        np.testing.assert_array_equal(
            np.asarray(quantize.sign_pm1(jnp.zeros((4, 64)))), expect)
        np.testing.assert_array_equal(
            np.asarray(unpack_signs(kops.cs_project_pack(phi, zeros))),
            expect)
        # packed words for +1-everywhere are all-ones bit patterns
        assert np.all(np.asarray(kops.cs_project_pack(phi, zeros))
                      == np.uint32(0xFFFFFFFF))

    def test_quantize_reexports_shared_helper(self):
        from repro.kernels import sign as sign_mod
        assert quantize.sign_pm1 is sign_mod.sign_pm1
        assert ref.sign_pm1 is sign_mod.sign_pm1


# --- Pallas kernel parity ------------------------------------------------------------

class TestKernelParity:
    def test_pack_kernel_matches_ref_and_f32_sign(self):
        phi, chunks = _proj_inputs(1, 6, 128, 512)
        packed = kops.cs_project_pack(phi, chunks)
        np.testing.assert_array_equal(
            np.asarray(packed), np.asarray(ref.cs_project_pack_ref(phi,
                                                                   chunks)))
        np.testing.assert_array_equal(
            np.asarray(unpack_signs(packed)),
            np.asarray(kops.cs_project_sign(phi, chunks)))

    def test_residual_planes_match_ref(self):
        phi, chunks = _proj_inputs(2, 5, 96, 256)
        y_packed = kops.cs_project_pack(phi, chunks)
        x = jax.random.normal(jax.random.PRNGKey(9), chunks.shape)
        plus, minus = kops.cs_pack_sign_residual(phi, x, y_packed)
        rp, rm = ref.sign_residual_planes_ref(phi, x, y_packed)
        np.testing.assert_array_equal(np.asarray(plus), np.asarray(rp))
        np.testing.assert_array_equal(np.asarray(minus), np.asarray(rm))
        # planes are disjoint: a lane is never both +2 and -2
        assert not np.any(np.asarray(plus) & np.asarray(minus))

    def test_backproject_packed_matches_f32_backproject(self):
        phi, chunks = _proj_inputs(3, 5, 96, 256)
        y_packed = kops.cs_project_pack(phi, chunks)
        x = jax.random.normal(jax.random.PRNGKey(10), chunks.shape)
        plus, minus = kops.cs_pack_sign_residual(phi, x, y_packed)
        resid = 2.0 * (unpack_bits(plus, jnp.float32)
                       - unpack_bits(minus, jnp.float32))
        # the equivalent f32 residual: y - sign(Φx) in {-2, 0, +2}
        y_f = unpack_signs(y_packed)
        sb = sign_pm1(jnp.einsum("sd,nd->ns", phi, x))
        np.testing.assert_array_equal(np.asarray(resid), np.asarray(y_f - sb))
        got = kops.backproject_packed(x, plus, minus, phi, 0.125)
        want = kops.backproject(x, y_f - sb, phi, 0.125)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_biht_packed_bitwise_matches_f32_biht(self):
        phi, chunks = _proj_inputs(4, 4, 128, 512)
        sparse = kops.topk_select(chunks, 50)[0]
        y = kops.cs_project_sign(phi, sparse)
        y_packed = pack_signs(y)
        a = fused_biht_packed(y_packed, phi, 50, iters=12, tau=1.0)
        b = kops.biht(y, phi, 50, iters=12, tau=1.0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_registry_biht_routes_packed(self):
        from repro.decode import DecodeConfig, decode
        phi, chunks = _proj_inputs(5, 3, 128, 512)
        sparse = kops.topk_select(chunks, 40)[0]
        y = kops.cs_project_sign(phi, sparse)
        y_packed = pack_signs(y)
        for use_kernels in (False, True):
            cfg = DecodeConfig(algorithm="biht", iters=8, packed=True,
                               use_kernels=use_kernels)
            cfg_f = DecodeConfig(algorithm="biht", iters=8, packed=False,
                                 use_kernels=use_kernels)
            np.testing.assert_array_equal(
                np.asarray(decode(y_packed, phi, 40, cfg)),
                np.asarray(decode(y, phi, 40, cfg_f)))


# --- end-to-end parity ---------------------------------------------------------------

class TestEndToEndParity:
    def _round(self, packed, use_kernels, *, D, chunk, measure, topk,
               iters=3):
        cfg = OBCSAAConfig(chunk=chunk, measure=measure, topk=topk,
                           biht_iters=iters, packed=packed,
                           use_kernels=use_kernels, noise_var=0.0)
        U = 4
        rng = np.random.default_rng(11)
        n_chunks = -(-D // chunk)
        grads = jnp.asarray(rng.standard_normal((U, n_chunks * chunk)),
                            jnp.float32)
        kw = jnp.ones((U,))
        beta = jnp.ones((U,))
        h = jnp.ones((U,))
        ghat, diag = simulate_round(cfg, grads, kw, beta, jnp.float32(1.0),
                                    h, jax.random.PRNGKey(3))
        return ghat

    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_simulate_round_packed_bitwise_equal(self, use_kernels):
        kw = dict(D=4096, chunk=1024, measure=256, topk=64)
        a = self._round(False, use_kernels, **kw)
        b = self._round(True, use_kernels, **kw)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.slow
    def test_simulate_round_packed_bitwise_equal_paper_scale(self):
        """Paper geometry: D = 50,890 (the §V CNN), D_c = 4096,
        S_c = 1024 — compress → MAC → decode identical bit for bit."""
        kw = dict(D=50890, chunk=4096, measure=1024, topk=409, iters=2)
        a = self._round(False, False, **kw)
        b = self._round(True, False, **kw)
        assert a.shape == b.shape and a.shape[0] >= 50890
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compress_chunks_packed_width(self):
        cfg = OBCSAAConfig(chunk=512, measure=128, topk=32, packed=True)
        flat = jnp.asarray(np.random.default_rng(1).standard_normal(2048),
                           jnp.float32)
        signs, mags = compress_chunks(cfg, flat)
        assert signs.dtype == jnp.uint32
        assert signs.shape == (4, 128 // PACK)
        cfg_f = OBCSAAConfig(chunk=512, measure=128, topk=32)
        signs_f, _ = compress_chunks(cfg_f, flat)
        np.testing.assert_array_equal(np.asarray(unpack_signs(signs)),
                                      np.asarray(signs_f))

    def test_comm_stats_packed_wire_ratio(self):
        cfg = OBCSAAConfig(chunk=4096, measure=1024, topk=409)
        st = comm_stats(cfg, D=50890)
        assert st["uplink_bits_f32"] == 32 * (13 * 1024 + 13)
        assert st["uplink_bits_packed"] == 13 * 1024 + 32 * 13
        assert st["packed_wire_ratio"] > 30       # ≥4x required, ~31x real
        assert st["uplink_bits_f32"] == 32 * 13 * 1024 + 32 * 13


# --- explicit shape validation -------------------------------------------------------

class TestShapeValidation:
    def test_unknown_mode(self):
        phi, chunks = _proj_inputs(6, 2, 64, 256)
        with pytest.raises(ValueError, match="mode"):
            cs_project.project(phi, chunks, mode="nope", interpret=True)

    def test_non_tiling_shapes(self):
        phi, chunks = _proj_inputs(7, 2, 64, 256)
        with pytest.raises(ValueError, match="tile"):
            cs_project.project(phi, chunks, mode="sign", interpret=True,
                               tiles=(2, 48, 256))

    def test_packed_measure_not_multiple_of_32(self):
        phi = jnp.ones((48, 256))
        chunks = jnp.ones((2, 256))
        with pytest.raises(ValueError, match="32"):
            cs_project.project(phi, chunks, mode="pack", interpret=True,
                               tiles=(2, 48, 256))

    def test_residual_modes_require_y(self):
        phi, chunks = _proj_inputs(8, 2, 64, 256)
        with pytest.raises(ValueError, match="y"):
            cs_project.project(phi, chunks, mode="pack_sign_residual",
                               interpret=True)

    def test_backproject_packed_bitplane_shapes(self):
        phi, chunks = _proj_inputs(9, 2, 64, 256)
        x = jnp.zeros((2, 256))
        good = jnp.zeros((2, 2), jnp.uint32)
        with pytest.raises(ValueError, match="bit-plane"):
            bp.backproject_packed(x, jnp.zeros((2, 3), jnp.uint32), good,
                                  phi, 1.0, interpret=True)
        with pytest.raises(ValueError, match="uint32"):
            bp.backproject_packed(x, jnp.zeros((2, 2), jnp.int32), good,
                                  phi, 1.0, interpret=True)

    def test_obcsaa_config_packed_measure(self):
        with pytest.raises(ValueError, match="32"):
            OBCSAAConfig(chunk=512, measure=100, topk=32, packed=True)

    def test_engine_rejects_bad_packed_geometry(self):
        from repro.engine.config import FLConfig
        from repro.engine.core import build_engine
        from repro.optim.optimizers import sgd
        ob = OBCSAAConfig(chunk=512, measure=100, topk=32)
        cfg = FLConfig(obcsaa=ob)
        object.__setattr__(ob, "packed", True)   # bypass config check to
        # prove the engine validates independently at build time
        with pytest.raises(ValueError, match="32"):
            build_engine(cfg, lambda p, d: jnp.sum(p ** 2), sgd(), 1024, 4,
                         lambda x: x)
