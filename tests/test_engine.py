"""Device-resident FL engine (repro.engine, DESIGN.md §11).

The load-bearing claims:
- scan engine ≡ host reference loop BITWISE at float32 (params + EF
  residual + decode warm-start carry) over ≥20 rounds;
- a vmapped arms lane is bitwise the corresponding single-arm run;
- the scan-safe batched ADMM matches the host-compacted fleet solver;
- the shared fade helper draws the paper's Rayleigh marginal (the old
  host loop drew half-normal |N(0,1)| — the fixed inconsistency);
- per-round scheduling stats are dense (no eval-gated holes);
- error feedback improves the final solution on a synthetic task.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as chan
from repro.theory import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig, simulate_round
from repro.core.sparsify import topk_sparsify
from repro.engine import EngineRun, FLConfig, make_arms, run_sweep
from repro.fl import FederatedTrainer

U = 4
CONST = AnalysisConstants(rho1=200.0, G=1.0)


# --- tiny task --------------------------------------------------------------------

@pytest.fixture(scope="module")
def task():
    """4-worker linear-regression task with a known optimum w*."""
    d_in, d_out, n = 24, 8, 16
    key = jax.random.PRNGKey(7)
    kw, kx, kn = jax.random.split(key, 3)
    w_star = jax.random.normal(kw, (d_in, d_out))
    x = jax.random.normal(kx, (U, n, d_in))
    y = jnp.einsum("ukd,dc->ukc", x, w_star) \
        + 0.01 * jax.random.normal(kn, (U, n, d_out))
    wd = {"x": x, "y": y}
    params0 = {"w": jnp.zeros((d_in, d_out))}

    def loss_fn(p, data):
        pred = data["x"] @ p["w"]
        return jnp.mean((pred - data["y"]) ** 2)

    def eval_fn(p):
        loss = jnp.mean((x.reshape(-1, d_in) @ p["w"]
                         - y.reshape(-1, d_out)) ** 2)
        return loss, -loss

    return wd, params0, loss_fn, eval_fn, w_star


@pytest.fixture(scope="module")
def mnist_task():
    """The paper's MLP at bitwise-stable shapes (D=50,890, 4096-chunks):
    tiny-dot fusions are context-dependent on XLA CPU, so the bitwise
    scan≡host claims are made where the bench makes them — on the
    MNIST-MLP task."""
    from repro.data import load_mnist, partition_workers
    from repro.models.mlp_mnist import init_mlp_mnist, mlp_mnist_loss
    xtr, ytr, _, _ = load_mnist()
    wx, wy = partition_workers(xtr, ytr, U, 4, seed=0)
    wd = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    params0 = init_mlp_mnist(jax.random.PRNGKey(0))

    def loss_fn(p, d):
        return mlp_mnist_loss(p, d["x"], d["y"])

    return wd, params0, loss_fn, None, None


def _ob(**kw):
    base = dict(chunk=64, measure=32, topk=8, biht_iters=4,
                recon_alg="iht", recon_tau=0.25)
    base.update(kw)
    return OBCSAAConfig(**base)


def _mnist_ob(**kw):
    base = dict(chunk=4096, measure=16, topk=8, biht_iters=2,
                recon_alg="iht", recon_tau=0.25)
    base.update(kw)
    return OBCSAAConfig(**base)


def _cfg(**kw):
    base = dict(aggregator="obcsaa", scheduler="greedy_batched",
                rounds=22, eval_every=8, obcsaa=_ob(), const=CONST,
                learning_rate=0.3)
    base.update(kw)
    return FLConfig(**base)


def _trainer(cfg, task_, **kw):
    wd, params0, loss_fn, eval_fn, _ = task_
    return FederatedTrainer(cfg, loss_fn, params0, wd,
                            np.full(U, 16.0), eval_fn=eval_fn, **kw)


def _tree_eq(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# --- engine ≡ host parity ---------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["greedy_batched", "admm_batched"])
def test_scan_equals_host_bitwise_warm_ef(mnist_task, scheduler):
    """The acceptance-criterion parity: scan engine ≡ host loop bitwise
    at float32 over ≥20 rounds, obcsaa with warm start + error feedback.
    Covers params, the EF residual carry and the decode warm-start carry."""
    wd, params0, loss_fn, _, _ = mnist_task
    cfg = FLConfig(aggregator="obcsaa", scheduler=scheduler, rounds=22,
                   obcsaa=_mnist_ob(warm_start=True), const=CONST,
                   error_feedback=True)
    scan_tr = FederatedTrainer(cfg, loss_fn, params0, wd, np.full(U, 4.0))
    scan_tr.run()
    host_tr = FederatedTrainer(dataclasses.replace(cfg, mode="host"),
                               loss_fn, params0, wd, np.full(U, 4.0))
    host_tr.run()
    assert scan_tr._mode == "scan" and host_tr._mode == "host"
    assert _tree_eq(scan_tr.params, host_tr.params)
    assert _tree_eq(scan_tr._state.residual, host_tr._state.residual)
    assert _tree_eq(scan_tr._state.decode_x0, host_tr._state.decode_x0)
    # dense stats streams agree (b_t to f32 tolerance: the caps product
    # h·√P/K may fuse differently across jit contexts — 1-ulp wiggle that
    # provably cancels out of the params trajectory above)
    assert [(s.round, s.n_scheduled) for s in scan_tr.sched_logs] \
        == [(s.round, s.n_scheduled) for s in host_tr.sched_logs]
    np.testing.assert_allclose([s.b_t for s in scan_tr.sched_logs],
                               [s.b_t for s in host_tr.sched_logs],
                               rtol=1e-6)


@pytest.mark.parametrize("opt_name,kw", [("momentum", {"beta": 0.9}),
                                         ("adam", {})])
def test_scan_equals_host_bitwise_optimizer_moments(mnist_task, opt_name,
                                                    kw):
    """§17 satellite of the optimizer-state tentpole: with a STATEFUL
    optimizer (momentum/adam moments riding the scan carry) and error
    feedback on, the scan engine still matches the host loop bitwise —
    params, every opt_state moment leaf, and the EF residual."""
    from repro.optim import make
    wd, params0, loss_fn, _, _ = mnist_task
    cfg = FLConfig(aggregator="obcsaa", scheduler="greedy_batched",
                   rounds=12, obcsaa=_mnist_ob(warm_start=True),
                   const=CONST, error_feedback=True)
    scan_tr = FederatedTrainer(cfg, loss_fn, params0, wd, np.full(U, 4.0),
                               optimizer=make(opt_name, **kw))
    scan_tr.run()
    host_tr = FederatedTrainer(dataclasses.replace(cfg, mode="host"),
                               loss_fn, params0, wd, np.full(U, 4.0),
                               optimizer=make(opt_name, **kw))
    host_tr.run()
    assert scan_tr._mode == "scan" and host_tr._mode == "host"
    assert _tree_eq(scan_tr.params, host_tr.params)
    assert _tree_eq(scan_tr.opt_state, host_tr.opt_state)
    assert _tree_eq(scan_tr._state.residual, host_tr._state.residual)
    # the moments did accumulate (non-trivial state went through parity)
    assert any(float(np.abs(np.asarray(x)).sum()) > 0
               for x in jax.tree_util.tree_leaves(scan_tr.opt_state))


def test_sweep_lane_equals_single_run(mnist_task):
    """vmap over arms must not change any lane's trajectory: lane i of a
    3-arm noise sweep matches the single-arm engine run at that σ² to
    f32 resolution (batched dots may re-associate — observed deviation is
    ~1e-8 after 8 rounds)."""
    wd, params0, loss_fn, _, _ = mnist_task
    cfg = FLConfig(aggregator="obcsaa", scheduler="greedy_batched",
                   obcsaa=_mnist_ob(warm_start=True), const=CONST)
    noise = [1e-6, 1e-4, 1e-2]
    out = run_sweep(cfg, loss_fn, params0, wd, np.full(U, 4.0),
                    rounds=8, noise_var=noise)
    single_cfg = dataclasses.replace(
        cfg, obcsaa=dataclasses.replace(cfg.obcsaa, noise_var=noise[2]))
    tr = FederatedTrainer(single_cfg, loss_fn, params0, wd,
                          np.full(U, 4.0))
    tr.run(8)
    lane = jax.tree_util.tree_map(lambda l: l[2], out["params"])
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(lane)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    assert out["n_scheduled"].shape == (3, 8)


def test_fused_ef_compression_matches_double_selection(task):
    """The engine's fused EF path (sparse_κ computed once, fed to the
    compressor presparsified) is bitwise the naive double-selection
    pipeline."""
    ob = _ob()
    grads = jax.random.normal(jax.random.PRNGKey(3), (U, 192))
    kw = jnp.full((U,), 16.0)
    beta = jnp.ones((U,))
    h = jnp.ones((U,))
    key = jax.random.PRNGKey(0)
    gc = grads.reshape(U, -1, ob.chunk)
    sp = topk_sparsify(gc, ob.topk)[0].reshape(U, -1)
    a, _ = simulate_round(ob, grads, kw, beta, 1.0, h, key)
    b, _ = simulate_round(ob, sp, kw, beta, 1.0, h, key,
                          presparsified=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# --- scan-safe ADMM ---------------------------------------------------------------

def test_admm_jit_matches_compacted_solver():
    """admm_solve_batched_jit (scan-safe, DESIGN.md §11) returns the same
    schedules as the host-compacted fleet solver."""
    from repro.sched import (BatchedProblem, admm_solve_batched,
                             admm_solve_batched_jit)
    h = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (16, 8))) + 1e-3
    bp = BatchedProblem.from_arrays(h, 3000.0, 10.0, 1e-4, D=50890,
                                    S=1000, kappa=1000, const=CONST)
    beta_c, bt_c, r_c = admm_solve_batched(bp)
    beta_j, bt_j, r_j = admm_solve_batched_jit(bp)
    assert np.array_equal(np.asarray(beta_c), np.asarray(beta_j))
    np.testing.assert_allclose(np.asarray(bt_c), np.asarray(bt_j),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r_c), np.asarray(r_j), rtol=1e-6)


# --- channel model (the fixed half-normal inconsistency) --------------------------

def _ks_rayleigh(samples) -> float:
    """Kolmogorov-Smirnov statistic of |h| against F(x) = 1 − exp(−x²),
    the |CN(0, 1)| (Rayleigh) magnitude CDF."""
    s = np.sort(np.asarray(samples).ravel())
    n = s.size
    cdf = 1.0 - np.exp(-s ** 2)
    i = np.arange(1, n + 1)
    return float(np.max(np.maximum(i / n - cdf, cdf - (i - 1) / n)))


def test_draw_fades_rayleigh_marginal_ks():
    """Regression for the channel-model fix: the shared helper draws
    Rayleigh magnitudes (KS vs the closed-form CDF at fixed seed), and
    the old half-normal |N(0,1)| draw is firmly rejected by the same
    statistic."""
    n = 20000
    h, _ = chan.draw_fades(jax.random.PRNGKey(11), (n,), clamp=False)
    assert _ks_rayleigh(h) < 0.015          # ≈1.95/√n at α=0.001
    half_normal = np.abs(np.random.default_rng(0).normal(size=n))
    assert _ks_rayleigh(half_normal) > 0.05


def test_gauss_markov_carry_keeps_rayleigh_marginal():
    """Stepping the Gauss-Markov recursion preserves the stationary
    CN(0, 1) marginal (magnitudes stay Rayleigh after many steps)."""
    key = jax.random.PRNGKey(13)
    _, g = chan.draw_fades(key, (4000,))
    for t in range(30):
        h, g = chan.draw_fades(jax.random.fold_in(key, t), rho=0.9,
                               prev=g, clamp=False)
    assert _ks_rayleigh(h) < 0.03


def test_trainer_and_scenario_share_fade_model(task):
    """Both consumers route through core.channel: the trainer's per-round
    magnitudes and the scenario generator's trajectories have the same
    Rayleigh marginal (KS on pooled draws)."""
    from repro.sched.scenario import ScenarioConfig, generate
    traj = generate(ScenarioConfig(rounds=64, cells=4, workers=16,
                                   model="iid"), jax.random.PRNGKey(3))
    assert _ks_rayleigh(np.asarray(traj)) < 0.03
    tr = _trainer(_cfg(rounds=4, eval_every=2), task)
    hs = [tr.run_round(t)["h"] for t in range(4)]
    assert np.all(np.concatenate(hs) >= chan.H_MIN)


# --- dense scheduling stats (RoundLog sparsity fix) -------------------------------

def test_sched_trajectory_dense_every_round(task):
    """n_scheduled/b_t are recorded EVERY round (the old loop only logged
    on eval rounds, leaving holes in scheduling trajectories)."""
    cfg = _cfg(rounds=15, eval_every=4)
    tr = _trainer(cfg, task)
    tr.run()
    traj = tr.sched_trajectory
    assert list(traj["round"]) == list(range(15))
    assert traj["n_scheduled"].shape == (15,)
    assert np.all(traj["n_scheduled"] >= 1)
    assert np.all(traj["b_t"] > 0)
    # eval stream stays on the eval cadence
    assert [l.round for l in tr.logs] == [0, 4, 8, 12, 14]


# --- error feedback ---------------------------------------------------------------

def test_error_feedback_improves_final_nmse(task):
    """EF compensates the top-κ compression bias: final NMSE
    ||w_T − w*||²/||w*||² improves with error_feedback=True on the
    synthetic regression task (aggressive sparsification, no AWGN)."""
    wd, params0, loss_fn, eval_fn, w_star = task
    nmse = {}
    for ef in (False, True):
        cfg = _cfg(aggregator="topk_aa", topk_dense=24, rounds=120,
                   eval_every=119, error_feedback=ef,
                   obcsaa=_ob(noise_var=1e-12), learning_rate=0.5)
        tr = _trainer(cfg, task)
        tr.run()
        w = np.asarray(tr.params["w"])
        nmse[ef] = float(np.sum((w - np.asarray(w_star)) ** 2)
                         / np.sum(np.asarray(w_star) ** 2))
    assert nmse[True] < 0.5 * nmse[False], nmse


# --- host reference path ----------------------------------------------------------

def test_enum_scheduler_runs_on_host_path(task):
    """The non-jittable enumeration oracle still works through the host
    reference path (auto mode resolution)."""
    cfg = _cfg(scheduler="enum", rounds=3, eval_every=2)
    tr = _trainer(cfg, task)
    assert tr._mode == "host"
    logs = tr.run()
    assert np.isfinite(logs[-1].loss)
    assert len(tr.sched_logs) == 3


def test_scan_mode_rejects_nonjittable_scheduler():
    with pytest.raises(ValueError, match="not jittable"):
        FLConfig(scheduler="enum", mode="scan").resolved_mode()


# --- launch wiring ----------------------------------------------------------------

def test_scan_train_step_and_scheduled_span_smoke():
    """launch/steps.py engine wiring: a whole span's P2 schedules solved
    in one batched call, then N rounds advanced by one jitted scan step
    (mesh train path, DESIGN.md §11)."""
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.launch import steps as steps_lib
    from repro.models.registry import build_model

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = get_config("mnist-mlp")
    tcfg = TrainConfig(aggregation="obcsaa", cs_chunk=512, cs_measure=64,
                       cs_topk=16, biht_iters=2)
    model = build_model(cfg)
    n = 3
    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt = steps_lib.make_optimizer(tcfg)
        opt_state = opt.init(params)
        D = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params))
        span = steps_lib.make_scheduled_round_span(mesh, tcfg, D, n)
        assert span["h"].shape == (n, 1) and span["beta"].shape == (n, 1)
        assert np.all(np.asarray(span["b_t"]) > 0)
        batch = {"x": jnp.ones((8, 784)),
                 "y": jnp.zeros((8,), jnp.int32)}
        step = jax.jit(steps_lib.make_scan_train_step(model, tcfg, mesh,
                                                      n))
        params2, opt_state, metrics = step(params, opt_state, batch, span)
        assert metrics["loss"].shape == (n,)
        assert np.all(np.isfinite(np.asarray(metrics["loss"])))
        moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(jax.tree_util.tree_leaves(params),
                                    jax.tree_util.tree_leaves(params2)))
        assert moved
