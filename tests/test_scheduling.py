"""P2 reference solvers: Algorithm 1 (enumeration) vs Algorithm 2 (ADMM)
vs greedy. The batched device solvers are tested against these oracles in
tests/test_sched.py (DESIGN.md §10)."""
import numpy as np
import pytest

from repro.theory import AnalysisConstants
from repro.sched.reference import (Problem, _rt, admm_solve, enumerate_solve,
                                   greedy_solve, optimal_bt)


def make_problem(U=6, seed=0, rho1=200.0, G=1.0):
    rng = np.random.default_rng(seed)
    return Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                   k_weights=np.full(U, 3000.0), p_max=10.0, noise_var=1e-4,
                   D=50890, S=1000, kappa=1000,
                   const=AnalysisConstants(rho1=rho1, G=G))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_enum_is_optimal_vs_random(seed):
    prob = make_problem(seed=seed)
    beta, bt, r = enumerate_solve(prob)
    rng = np.random.default_rng(seed + 100)
    for _ in range(50):
        b = (rng.random(prob.U) > 0.5).astype(np.float64)
        if b.sum() == 0:
            continue
        r_rand = _rt(prob, b, optimal_bt(prob, b))
        assert r <= r_rand + 1e-9


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_admm_close_to_enum(seed):
    prob = make_problem(seed=seed)
    _, _, r_enum = enumerate_solve(prob)
    _, _, r_admm = admm_solve(prob)
    assert r_admm <= r_enum * 1.10 + 1e-6   # paper: ADMM suboptimal but close


@pytest.mark.parametrize("seed", [0, 1, 2, 5])
def test_greedy_equals_enum_for_equal_k(seed):
    """With equal K_i the optimum is a prefix of the channel-cap order."""
    prob = make_problem(seed=seed)
    _, _, r_enum = enumerate_solve(prob)
    _, _, r_greedy = greedy_solve(prob)
    assert np.isclose(r_enum, r_greedy, rtol=1e-9)


def test_bt_sits_on_power_boundary():
    prob = make_problem()
    beta = np.ones(prob.U)
    bt = optimal_bt(prob, beta)
    p = (prob.k_weights * bt / prob.h) ** 2
    assert np.isclose(p.max(), prob.p_max, rtol=1e-9)
    # R_t decreasing in b_t below the boundary
    assert _rt(prob, beta, bt) <= _rt(prob, beta, bt * 0.5)


def test_scheduling_tradeoff_rho1():
    """Large ρ₁ (costly exclusion) schedules everyone; tiny ρ₁ with large G
    (costly sparsification error per worker) schedules fewer."""
    all_in = enumerate_solve(make_problem(rho1=500.0, G=0.5))[0]
    assert all_in.sum() == len(all_in)
    few = enumerate_solve(make_problem(rho1=0.01, G=10.0))[0]
    assert few.sum() < len(few)


def test_admm_scales_to_large_u():
    prob = make_problem(U=64, seed=9)
    beta, bt, r = admm_solve(prob)
    assert beta.shape == (64,)
    assert bt > 0 and np.isfinite(r)
    p = (prob.k_weights * beta * bt / prob.h) ** 2
    assert (p <= prob.p_max * (1 + 1e-6)).all()
