"""1-bit CS decoders: exact-sparse recovery, noise robustness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.measurement import make_phi
from repro.decode import biht_sign, hard_threshold, iht


def sparse_vec(key, d, k):
    k1, k2 = jax.random.split(key)
    idx = jax.random.choice(k1, d, (k,), replace=False)
    return jnp.zeros((d,)).at[idx].set(jax.random.normal(k2, (k,)))


def test_hard_threshold_keeps_k():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    h = hard_threshold(x, 17)
    assert (np.asarray(h != 0).sum(axis=-1) == 17).all()


@pytest.mark.parametrize("d,s,k", [(512, 256, 16), (1024, 512, 32)])
def test_iht_exact_recovery(d, s, k):
    phi = make_phi(3, s, d)
    x = sparse_vec(jax.random.PRNGKey(5), d, k)
    xh = iht(phi @ x, phi, k, iters=50, tau=1.0)
    assert float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x)) < 1e-3


def test_iht_noise_robust():
    d, s, k = 1024, 512, 24
    phi = make_phi(4, s, d)
    x = sparse_vec(jax.random.PRNGKey(6), d, k)
    y = phi @ x + 0.01 * jax.random.normal(jax.random.PRNGKey(7), (s,))
    xh = iht(y, phi, k, iters=50)
    assert float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x)) < 0.1


@pytest.mark.parametrize("d,s,k", [(1024, 512, 16), (2048, 1024, 32)])
def test_biht_direction_recovery(d, s, k):
    """1-bit measurements are scale-invariant: BIHT recovers direction."""
    phi = make_phi(8, s, d)
    x = sparse_vec(jax.random.PRNGKey(9), d, k)
    y = jnp.where(phi @ x >= 0, 1.0, -1.0)
    xh = biht_sign(y, phi, k, iters=40)
    xn = x / jnp.linalg.norm(x)
    assert float(jnp.dot(xh, xn)) > 0.95
    assert np.isclose(float(jnp.linalg.norm(xh)), 1.0, rtol=1e-5)


def test_biht_batched_rows_independent():
    d, s, k = 512, 256, 8
    phi = make_phi(10, s, d)
    xs = jnp.stack([sparse_vec(jax.random.PRNGKey(i), d, k)
                    for i in (1, 2, 3)])
    ys = jnp.where(jnp.einsum("sd,nd->ns", phi, xs) >= 0, 1.0, -1.0)
    batched = biht_sign(ys, phi, k, iters=20)
    single = jnp.stack([biht_sign(ys[i], phi, k, iters=20) for i in range(3)])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(single),
                               atol=1e-5)
