"""Data pipeline, optimizers, checkpointing, error floor closed forms."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.theory import (AnalysisConstants, bt_term, lemma1_error_bound,
                          rt_objective, theorem1_rate)
from repro.data import load_mnist, partition_workers, token_stream
from repro.optim import adam, momentum, sgd, with_error_feedback
from repro.optim.schedules import cosine_decay, warmup_cosine


def test_synthetic_mnist_deterministic_and_learnable():
    x1, y1, xt, yt = load_mnist()
    x2, y2, _, _ = load_mnist()
    assert x1.shape == (60000, 784) and xt.shape == (10000, 784)
    np.testing.assert_array_equal(x1[:100], x2[:100])
    assert 0 <= x1.min() and x1.max() <= 1.0
    assert set(np.unique(y1)) == set(range(10))


def test_partition_iid_and_noniid():
    x, y, _, _ = load_mnist()
    wx, wy = partition_workers(x, y, 4, 100, iid=True, seed=0)
    assert wx.shape == (4, 100, 784)
    _, wy_n = partition_workers(x, y, 4, 500, iid=False, seed=0)
    # non-iid: majority classes dominate
    for w in range(4):
        major = {(2 * w) % 10, (2 * w + 1) % 10}
        frac = np.isin(wy_n[w], list(major)).mean()
        assert frac > 0.4


def test_token_stream_shapes():
    t, g = token_stream(4, 32, 100)
    assert t.shape == (4, 32) and g.shape == (4, 32)
    assert t.max() < 100


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adam"])
def test_optimizers_descend_quadratic(opt_name):
    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[opt_name]()
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_error_feedback_accumulates_residual():
    def comp(flat):
        q = jnp.where(jnp.abs(flat) >= jnp.max(jnp.abs(flat)), flat, 0.0)
        return q, q

    ef = with_error_feedback(comp)
    g = jnp.asarray([1.0, 0.6, 0.3])
    wire, resid = ef(g, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(resid), [0.0, 0.6, 0.3])
    wire2, resid2 = ef(g, resid)
    # accumulated residual promotes the second coordinate
    assert float(wire2[1]) != 0.0


def test_schedules():
    s = cosine_decay(1.0, 100)
    assert float(s(0)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.1, abs=1e-6)
    w = warmup_cosine(1.0, 10, 110)
    assert float(w(5)) == pytest.approx(0.5)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, tree)
        assert latest_step(d) == 7
        back = restore(d, 7, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        assert back["b"]["c"].dtype == jnp.bfloat16


def test_theorem1_terms_positive_and_monotone():
    c = AnalysisConstants()
    common = dict(D=50890, S=1000, kappa=1000, k_weights=np.full(10, 3000.0),
                  b_t=0.001, noise_var=1e-4)
    full = lemma1_error_bound(c, beta=np.ones(10), **common)
    # larger kappa -> smaller error (Remark 1)
    smaller = lemma1_error_bound(
        c, beta=np.ones(10), D=50890, S=1000, kappa=5000,
        k_weights=np.full(10, 3000.0), b_t=0.001, noise_var=1e-4)
    assert float(smaller) < float(full)
    # larger S -> smaller error (Remark 1)
    bigger_s = lemma1_error_bound(
        c, beta=np.ones(10), D=50890, S=10000, kappa=1000,
        k_weights=np.full(10, 3000.0), b_t=0.001, noise_var=1e-4)
    assert float(bigger_s) < float(full)
    bt = bt_term(c, beta=np.ones(10), **common)
    rt = rt_objective(c, beta=np.ones(10), **common)
    assert float(rt) == pytest.approx(2 * c.L * float(bt), rel=1e-6)
    rate = theorem1_rate(c, T=100, f0_minus_fstar=1.0,
                         bt_sum=100 * float(bt))
    assert rate > 0
