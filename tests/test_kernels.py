"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,s,d", [(8, 128, 512), (64, 256, 1024),
                                   (128, 512, 4096), (130, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cs_project_sign(n, s, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + s))
    phi = (jax.random.normal(k1, (s, d)) / np.sqrt(s)).astype(dtype)
    chunks = jax.random.normal(k2, (n, d)).astype(dtype)
    got = ops.cs_project_sign(phi, chunks)
    want = ref.cs_project_sign_ref(phi, chunks)
    # signs must agree where the projection isn't borderline-zero
    proj = jnp.einsum("sd,nd->ns", phi.astype(jnp.float32),
                      chunks.astype(jnp.float32))
    solid = jnp.abs(proj) > 1e-3
    assert bool(jnp.all(jnp.where(solid, got == want, True)))
    assert bool(jnp.all(jnp.abs(got) == 1.0))


@pytest.mark.parametrize("n,d,k", [(8, 256, 5), (64, 1024, 64),
                                   (128, 4096, 409), (3, 512, 1)])
def test_topk_select(n, d, k):
    x = jax.random.normal(jax.random.PRNGKey(n * d + k), (n, d))
    got_v, got_m = ops.topk_select(x, k)
    want_v, want_m = ref.topk_select_ref(x, k)
    assert got_m.sum(axis=-1).max() == k and got_m.sum(axis=-1).min() == k
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=0, atol=0)


@pytest.mark.parametrize("n,s,d", [(8, 128, 512), (64, 256, 1024)])
@pytest.mark.parametrize("tau", [1.0, 0.01])
def test_backproject(n, s, d, tau):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (n, d))
    r = jax.random.normal(ks[1], (n, s))
    phi = jax.random.normal(ks[2], (s, d)) / np.sqrt(s)
    got = ops.backproject(x, r, phi, tau)
    want = ref.backproject_ref(x, r, phi, tau)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("iters", [0, 3, 10])
def test_biht_composition(iters):
    n, s, d, k = 8, 256, 1024, 24
    ks = jax.random.split(jax.random.PRNGKey(iters), 3)
    phi = jax.random.normal(ks[0], (s, d)) / np.sqrt(s)
    x_true, _ = ref.topk_select_ref(jax.random.normal(ks[1], (n, d)), k)
    y = ref.sign_pm1(jnp.einsum("sd,nd->ns", phi, x_true))
    got = ops.biht(y, phi, k, iters, 1.0)
    want = ref.biht_ref(y, phi, k, iters, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_biht_recovers_direction():
    n, s, d, k = 4, 512, 1024, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    phi = jax.random.normal(ks[0], (s, d)) / np.sqrt(s)
    x_true, _ = ref.topk_select_ref(jax.random.normal(ks[1], (n, d)), k)
    y = ref.sign_pm1(jnp.einsum("sd,nd->ns", phi, x_true))
    xh = ops.biht(y, phi, k, 30, 1.0)
    xn = x_true / jnp.linalg.norm(x_true, axis=-1, keepdims=True)
    cos = jnp.sum(xh * xn, axis=-1)
    assert float(cos.min()) > 0.95
