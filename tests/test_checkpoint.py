"""Checkpoint/restore (DESIGN.md §14): atomic step dirs, template-strict
validation, corruption errors that say what to do, engine-wired resume that
is bit-for-bit identical to the uninterrupted sweep — including restoring
onto a differently-sized mesh (1 -> 8 and 8 -> 1 devices) and resuming the
train CLI."""
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.core.obcsaa import OBCSAAConfig
from repro.engine import EngineRun, FLConfig, make_arms

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --- io primitives ---------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
            "n": (jnp.int32(7), {"deep": jnp.zeros((2, 2), jnp.float64)})}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    path = checkpoint.save(d, 3, tree)
    assert path.endswith("step_00000003") and os.path.isdir(path)
    assert checkpoint.latest_step(d) == 3
    out = checkpoint.restore(d, 3, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float64),
                              np.asarray(b, np.float64)), (a, b)
    # overwriting a step is atomic-in-place; later steps win latest_step
    checkpoint.save(d, 3, tree)
    checkpoint.save(d, 10, tree)
    assert checkpoint.latest_step(d) == 10


def test_restore_validation_errors(tmp_path):
    d = str(tmp_path / "ck")
    checkpoint.save(d, 2, _tree())
    with pytest.raises(FileNotFoundError, match="available steps.*2"):
        checkpoint.restore(d, 5, _tree())
    with pytest.raises(FileNotFoundError, match="none"):
        checkpoint.restore(str(tmp_path / "nowhere"), 0, _tree())
    with pytest.raises(ValueError, match="leaves, template has"):
        checkpoint.restore(d, 2, {"only": jnp.zeros(3)})
    bad = _tree()
    bad["w"] = jnp.zeros((9, 9))
    with pytest.raises(ValueError, match="geometry"):
        checkpoint.restore(d, 2, bad)


def test_restore_dtype_strict_message(tmp_path):
    """Restore validates per-leaf dtypes against the template: optimizer
    moments and round carries restore dtype-strict, a silent cast would
    break bitwise resume (DESIGN.md §17). The one legitimate aliasing is
    ml_dtypes storage — a bfloat16 template accepts the float32 bytes
    ``save`` wrote for it."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, {"m": jnp.zeros((3,), jnp.float32)})
    bad = {"m": jax.ShapeDtypeStruct((3,), jnp.float16)}
    with pytest.raises(ValueError, match=r"has dtype float32, template "
                                         r"expects float16.*dtype-strict"):
        checkpoint.restore(d, 1, bad)
    with pytest.raises(ValueError, match=r"expects int32"):
        checkpoint.restore(d, 1, {"m": jax.ShapeDtypeStruct((3,),
                                                            jnp.int32)})
    out = checkpoint.restore(d, 1, {"m": jax.ShapeDtypeStruct(
        (3,), jnp.bfloat16)})        # bf16 is STORED as f32: accepted
    assert out["m"].dtype == jnp.bfloat16


@pytest.mark.parametrize("victim", ["tree.msgpack", "arrays.npz"])
def test_corrupt_checkpoint_errors(tmp_path, victim):
    """A truncated/garbled file must surface as ValueError telling the
    user which file broke and to resume from an earlier step — not as a
    raw zipfile/msgpack traceback."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, _tree())
    p = os.path.join(checkpoint.step_dir(d, 1), victim)
    blob = open(p, "rb").read()
    with open(p, "wb") as f:       # truncate to a prefix
        f.write(blob[:max(1, len(blob) // 3)])
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(d, 1, _tree())
    msg = str(ei.value)
    assert "corrupt or truncated" in msg and victim in msg
    assert "resume from an earlier step" in msg


# --- engine-wired resume ---------------------------------------------------------

def _sweep_fixture():
    U, D = 4, 1200
    cfg = FLConfig(aggregator="obcsaa", scheduler="all", rounds=8,
                   eval_every=3, error_feedback=True,
                   obcsaa=OBCSAAConfig(chunk=256, measure=64, topk=16,
                                       biht_iters=3, warm_start=True,
                                       recon_alg="iht"))
    params0 = {"w": jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)}
    data = {"c": jax.random.normal(jax.random.PRNGKey(3), (U, D))}

    def loss(p, d):
        return 0.5 * jnp.sum((p["w"] - d["c"]) ** 2)

    def ev(p):
        return jnp.sum(p["w"] ** 2), jnp.float32(0.0)

    def run():
        return EngineRun(cfg, loss, params0, data, np.ones(U), eval_fn=ev)
    return cfg, run


def _trim(ckpt_dir, keep_to):
    for sub in os.listdir(ckpt_dir):
        if int(sub.split("_")[1]) > keep_to:
            shutil.rmtree(os.path.join(ckpt_dir, sub))


def test_engine_resume_bitwise(tmp_path):
    """Kill a sweep at an eval boundary, resume: the full carry (params /
    fade / prev-beta / warm-start / EF residual), the stat tail and the
    eval stream must equal the uninterrupted run bit for bit."""
    cfg, mk = _sweep_fixture()
    arms = make_arms(cfg, noise_var=[1e-4, 1e-2])
    d = str(tmp_path / "sweep")
    full = mk().run_sweep(arms, ckpt_dir=d)
    assert full["t_start"] == 0
    # chunk boundaries for rounds=8, eval_every=3 are 1, 4, 7, 8
    assert checkpoint.latest_step(d) == 8
    _trim(d, 4)
    res = mk().run_sweep(arms, ckpt_dir=d, resume=True)
    assert res["t_start"] == 4
    eq = jax.tree_util.tree_map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        full["state"], res["state"])
    assert all(jax.tree_util.tree_leaves(eq)), eq
    n = res["n_scheduled"].shape[1]
    assert np.array_equal(full["n_scheduled"][:, -n:], res["n_scheduled"])
    assert np.array_equal(full["b_t"][:, -n:], res["b_t"])
    assert np.array_equal(full["rt_bound"][:, -n:], res["rt_bound"])
    assert np.array_equal(full["loss"][:, -1], res["loss"][:, -1])
    # resuming past the end is a no-op that still returns the final state
    done = mk().run_sweep(arms, ckpt_dir=d, resume=True)
    assert done["t_start"] in (7, 8)


def test_engine_resume_rejects_different_arms(tmp_path):
    cfg, mk = _sweep_fixture()
    arms = make_arms(cfg, noise_var=[1e-4, 1e-2])
    d = str(tmp_path / "sweep")
    mk().run_sweep(arms, ckpt_dir=d)
    other = make_arms(cfg, noise_var=[1e-4, 5e-2])
    with pytest.raises(ValueError, match="different arms"):
        mk().run_sweep(other, ckpt_dir=d, resume=True)


def test_engine_resume_requires_ckpt_dir():
    cfg, mk = _sweep_fixture()
    with pytest.raises(ValueError, match="ckpt_dir"):
        mk().run_sweep(make_arms(cfg, noise_var=[1e-4]), resume=True)


SCRIPT_ELASTIC = textwrap.dedent("""
    import os, shutil, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine import EngineRun, FLConfig, make_arms
    from repro.optim import make as make_opt

    U, D = 4, 1200
    cfg = FLConfig(aggregator="obcsaa", scheduler="all", rounds=8,
                   eval_every=3, error_feedback=True,
                   obcsaa=OBCSAAConfig(chunk=256, measure=64, topk=16,
                                       biht_iters=3, warm_start=True,
                                       recon_alg="iht"))
    params0 = {"w": jnp.linspace(-1.0, 1.0, D, dtype=jnp.float32)}
    data = {"c": jax.random.normal(jax.random.PRNGKey(3), (U, D))}
    loss = lambda p, d: 0.5 * jnp.sum((p["w"] - d["c"]) ** 2)
    arms = make_arms(cfg, noise_var=[1e-4, 1e-3, 1e-2, 1e-1])
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # adam: the checkpoint carries NON-TRIVIAL optimizer moments through
    # the device-layout transitions (DESIGN.md §17)
    mk = lambda: EngineRun(cfg, loss, params0, data, np.ones(U),
                           optimizer=make_opt("adam"))

    def trim(d, keep):
        for s in os.listdir(d):
            if int(s.split("_")[1]) > keep:
                shutil.rmtree(os.path.join(d, s))

    def assert_bitwise(a, b, what):
        eq = jax.tree_util.tree_map(
            lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
            a, b)
        assert all(jax.tree_util.tree_leaves(eq)), (what, eq)

    base = tempfile.mkdtemp()
    # uninterrupted single-placement run = the reference trajectory
    ref = mk().run_sweep(arms, eval_every=3)["state"]
    assert float(np.abs(np.asarray(ref.opt_state["m"]["w"])).sum()) > 0
    assert float(np.abs(np.asarray(ref.opt_state["v"]["w"])).sum()) > 0

    # 1 -> 8: save on default placement, finish on the 8-device mesh with
    # the arm axis sharded over the workers
    d1 = os.path.join(base, "from1")
    mk().run_sweep(arms, ckpt_dir=d1, eval_every=3)
    trim(d1, 4)
    r8 = mk().run_sweep(arms, ckpt_dir=d1, resume=True,
                        mesh=mesh, eval_every=3)
    assert r8["t_start"] == 4
    assert_bitwise(ref, r8["state"], "1->8")

    # 8 -> 1: save while arms-sharded on the mesh, finish single-placement
    d8 = os.path.join(base, "from8")
    mk().run_sweep(arms, ckpt_dir=d8, mesh=mesh, eval_every=3)
    trim(d8, 4)
    r1 = mk().run_sweep(arms, ckpt_dir=d8, resume=True,
                        eval_every=3)
    assert r1["t_start"] == 4
    assert_bitwise(ref, r1["state"], "8->1")
    print("OK")
""")


@pytest.mark.slow
def test_mesh_elastic_resume_8dev():
    """A sweep checkpoint saved under one device layout restores onto a
    differently-sized mesh (1 -> 8 and 8 -> 1) and finishes bit-for-bit
    identical to the uninterrupted run — checkpoints hold plain host
    arrays, placement is reapplied at restore (DESIGN.md §14)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_ELASTIC], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout


# --- train CLI -------------------------------------------------------------------

@pytest.mark.slow
def test_train_cli_resume(tmp_path):
    """``--resume`` continues from the latest step and reaches the same
    final parameters+optimizer state, bit for bit, as the uninterrupted
    run (step RNG/schedules index absolute steps)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    # collection imports launch.dryrun, which pins a 512-device XLA flag
    # in this process — don't leak it into the CLI child
    env.pop("XLA_FLAGS", None)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "gemma2-2b", "--smoke", "--batch", "2", "--seq", "32",
            "--cs-chunk", "512", "--cs-measure", "64", "--cs-topk", "16"]

    def run(extra):
        r = subprocess.run(base + extra, env=env, capture_output=True,
                           text=True, timeout=560)
        assert r.returncode == 0, \
            f"ARGS {extra}\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
        return r.stdout

    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    run(["--steps", "4", "--ckpt-dir", da])
    run(["--steps", "2", "--ckpt-dir", db])
    out = run(["--steps", "4", "--ckpt-dir", db, "--resume"])
    assert "resumed from step 2" in out
    a = np.load(os.path.join(checkpoint.step_dir(da, 4), "arrays.npz"))
    b = np.load(os.path.join(checkpoint.step_dir(db, 4), "arrays.npz"))
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        assert np.array_equal(a[k], b[k]), f"leaf {k} differs after resume"
