"""repro.decode: registry dispatch, fused-Pallas IHT bit-parity with the
seed einsum decoder, warm-start NMSE gains on correlated gradients, and
sharded decode == single-device decode on an 8-device CPU mesh
(subprocess, same pattern as test_dist_sharding.py)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.measurement import make_phi
from repro.decode import (DecodeConfig, decode, fused_iht, get_decoder, iht,
                          list_decoders, register_decoder)
from repro.decode import registry as dec_registry
from repro.kernels.ref import topk_select_ref

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _measurements(n=8, s=512, d=1024, k_true=60, noise=0.01, seed=0):
    phi = make_phi(seed + 3, s, d)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    x_true, _ = topk_select_ref(x, k_true)
    y = jnp.einsum("sd,nd->ns", phi, x_true)
    y = y + noise * jax.random.normal(jax.random.PRNGKey(seed + 1), (n, s))
    return y, phi, x_true


# --- registry ---------------------------------------------------------------------

def test_registry_builtins_present():
    names = set(list_decoders())
    assert {"iht", "biht", "niht", "iht_warm", "iht_fused"} <= names


def test_registry_unknown_decoder_raises():
    with pytest.raises(ValueError, match="unknown decoder"):
        get_decoder("does_not_exist")
    y, phi, _ = _measurements(n=2, s=128, d=256)
    with pytest.raises(ValueError, match="registered"):
        decode(y, phi, 8, DecodeConfig(algorithm="nope"))


def test_registry_dispatch_matches_direct_call():
    y, phi, _ = _measurements()
    cfg = DecodeConfig(algorithm="iht", iters=6, tau=1.0)
    got = decode(y, phi, 40, cfg)
    want = iht(y, phi, 40, iters=6, tau=1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_registry_custom_decoder_roundtrip():
    @register_decoder("test_zero")
    def _zero(y, phi, k, cfg, x0):
        return jnp.zeros(y.shape[:-1] + (phi.shape[1],), y.dtype)

    try:
        y, phi, _ = _measurements(n=2, s=128, d=256)
        out = decode(y, phi, 8, DecodeConfig(algorithm="test_zero"))
        assert not np.asarray(out).any()
        assert "test_zero" in list_decoders()
    finally:
        del dec_registry._REGISTRY["test_zero"]


def test_warm_state_withheld_from_cold_decoders():
    """decode() forwards x0 only to warm-capable decoders (DESIGN.md §9)."""
    y, phi, x_true = _measurements()
    junk = 100.0 * jax.random.normal(jax.random.PRNGKey(9), x_true.shape)
    cold_cfg = DecodeConfig(algorithm="iht", iters=6, tau=1.0)
    a = decode(y, phi, 40, cold_cfg)
    b = decode(y, phi, 40, cold_cfg, x0=junk)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    warm_cfg = DecodeConfig(algorithm="iht_warm", iters=6, tau=1.0)
    c = decode(y, phi, 40, warm_cfg, x0=junk)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_validate_raise_catches_divergent_tau():
    """Fixed-step IHT silently diverges past the restricted stability edge
    τ·λ̂ ≥ 2 (DESIGN.md §13) — validate='raise' turns that into a
    ValueError naming the measured λ̂ and the safe τ range."""
    y, phi, _ = _measurements()
    with pytest.raises(ValueError, match="unstable"):
        decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30, tau=1.0,
                                         validate="raise"))
    # the divergence the guard prevents is real: unguarded it blows up
    raw = decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30,
                                           tau=1.0))
    assert float(jnp.max(jnp.abs(raw))) > 1e6


def test_validate_passes_stable_tau_bitwise():
    """A stable τ decodes through the guard bit-identically to the
    unguarded path — the guard is trace-invisible when it doesn't fire."""
    y, phi, _ = _measurements()
    a = decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30,
                                         tau=0.25, validate="raise"))
    b = decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30,
                                         tau=0.25))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_validate_fallback_swaps_in_niht():
    y, phi, _ = _measurements()
    f = decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30, tau=1.0,
                                         validate="fallback"))
    n = decode(y, phi, 256, DecodeConfig(algorithm="niht", iters=30))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(n))
    assert bool(jnp.all(jnp.isfinite(f)))


def test_validate_under_jit_is_a_cond():
    """Traced decode cannot raise — both modes become a lax.cond between
    the requested decoder and NIHT, selected by the traced predicate."""
    y, phi, _ = _measurements()
    n = decode(y, phi, 256, DecodeConfig(algorithm="niht", iters=30))
    bad = jax.jit(lambda yy, pp: decode(yy, pp, 256, DecodeConfig(
        algorithm="iht", iters=30, tau=1.0, validate="raise")))(y, phi)
    np.testing.assert_array_equal(np.asarray(bad), np.asarray(n))
    ok = jax.jit(lambda yy, pp: decode(yy, pp, 256, DecodeConfig(
        algorithm="iht", iters=30, tau=0.25, validate="fallback")))(y, phi)
    eager = decode(y, phi, 256, DecodeConfig(algorithm="iht", iters=30,
                                             tau=0.25))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(eager))


def test_validate_unknown_mode_raises():
    y, phi, _ = _measurements()
    with pytest.raises(ValueError, match="validate"):
        decode(y, phi, 64, DecodeConfig(algorithm="iht", validate="maybe"))


def test_restricted_spectral_estimate_brackets_divergence():
    """The guard's λ̂ is calibrated: the empirical blow-up τ sits inside
    (1/λ̂ is safe, 2/λ̂ is the edge) — see IHT_STABILITY_BOUND."""
    from repro.decode.iht import (IHT_STABILITY_BOUND, iht_step_stable,
                                  restricted_spectral_estimate)
    y, phi, x_true = _measurements()
    lam = float(restricted_spectral_estimate(phi, 256))
    assert 3.0 < lam < 6.0
    safe_tau = 0.5 / lam
    edge_tau = (IHT_STABILITY_BOUND + 0.5) / lam
    assert bool(iht_step_stable(phi, 256, safe_tau))
    assert not bool(iht_step_stable(phi, 256, edge_tau))
    out = iht(y, phi, 256, iters=40, tau=safe_tau)
    assert float(jnp.max(jnp.abs(out))) < 1e3
    out = iht(y, phi, 256, iters=40, tau=edge_tau)
    assert float(jnp.max(jnp.abs(out))) > 1e3


def test_ht_bisect_matches_sort_on_generic_values():
    y, phi, _ = _measurements()
    a = decode(y, phi, 40, DecodeConfig(algorithm="iht", iters=6, tau=1.0,
                                        ht="sort"))
    b = decode(y, phi, 40, DecodeConfig(algorithm="iht", iters=6, tau=1.0,
                                        ht="bisect"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --- fused-Pallas IHT parity ------------------------------------------------------

def test_fused_iht_bitwise_matches_seed_iht():
    """Cold-start parity: the fused kernel loop == the einsum decoder
    bit for bit in interpret mode (DESIGN.md §9 tiling policy)."""
    y, phi, _ = _measurements(n=13, s=512, d=1024)  # odd n exercises row pad
    ref = jax.jit(lambda y: iht(y, phi, 64, iters=8, tau=1.0))(y)
    got = jax.jit(lambda y: fused_iht(y, phi, 64, iters=8, tau=1.0,
                                      interpret=True))(y)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.slow
def test_fused_iht_bitwise_paper_chunk_scale():
    """Same parity at the paper's chunk geometry (D_c=4096, S_c=1024,
    13 chunks = D=50,890 padded, κ̄=512)."""
    y, phi, _ = _measurements(n=13, s=1024, d=4096, k_true=409)
    ref = jax.jit(lambda y: iht(y, phi, 512, iters=5, tau=0.25))(y)
    got = jax.jit(lambda y: fused_iht(y, phi, 512, iters=5, tau=0.25,
                                      interpret=True))(y)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_fused_iht_warm_start_consumed():
    y, phi, x_true = _measurements()
    cold = fused_iht(y, phi, 64, iters=2, tau=1.0, interpret=True)
    warm = fused_iht(y, phi, 64, iters=2, tau=1.0, x0=x_true,
                     interpret=True)
    assert not np.array_equal(np.asarray(cold), np.asarray(warm))
    # warm from the truth after 2 iterations must be at least as accurate
    err_c = float(jnp.linalg.norm(cold - x_true))
    err_w = float(jnp.linalg.norm(warm - x_true))
    assert err_w <= err_c


# --- warm start on correlated rounds ----------------------------------------------

def test_warm_start_improves_nmse_on_correlated_gradients():
    """Round t's decode seeded with round t−1's estimate beats cold start
    at the same (small) iteration budget — the temporal-correlation gain
    the warm-start decoder exists for (DESIGN.md §9)."""
    n, s, d, k_true, k = 6, 512, 1024, 60, 128
    tau = 0.25      # stable fixed step at this decode budget (k = S/4; see
    # benchmarks/decoders_bench.py on the restricted operator norm)
    phi = make_phi(11, s, d)
    x_prev, _ = topk_select_ref(
        jax.random.normal(jax.random.PRNGKey(0), (n, d)), k_true)
    innov = 0.15 * jax.random.normal(jax.random.PRNGKey(1), (n, d))
    x_next_dense = x_prev + innov * (x_prev != 0)     # support-preserving drift
    x_next, _ = topk_select_ref(x_next_dense, k_true)
    y_prev = jnp.einsum("sd,nd->ns", phi, x_prev)
    y_next = jnp.einsum("sd,nd->ns", phi, x_next)

    # round t−1 estimate (well-converged), then a tight budget for round t
    x0 = decode(y_prev, phi, k, DecodeConfig("iht", iters=30, tau=tau))
    cold = decode(y_next, phi, k, DecodeConfig("iht", iters=3, tau=tau))
    warm = decode(y_next, phi, k, DecodeConfig("iht_warm", iters=3, tau=tau),
                  x0=x0)

    def nmse(xh):
        return float(jnp.sum((xh - x_next) ** 2) / jnp.sum(x_next ** 2))

    assert nmse(warm) < nmse(cold)


# --- sharded decode (8-device CPU mesh, subprocess) -------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.measurement import make_phi
    from repro.core.obcsaa import OBCSAAConfig, reconstruct_chunks
    from repro.decode import DecodeConfig, decode
    from repro.kernels.ref import topk_select_ref

    n, s, d, k = 16, 256, 512, 64
    phi = make_phi(5, s, d)
    x_true, _ = topk_select_ref(
        jax.random.normal(jax.random.PRNGKey(0), (n, d)), 32)
    y = jnp.einsum("sd,nd->ns", phi, x_true)

    cfgs = [DecodeConfig("iht", iters=8, tau=1.0, ht="bisect"),
            DecodeConfig("niht", iters=8, ht="bisect"),
            DecodeConfig("biht", iters=8, ht="bisect")]

    # single-device reference (no mesh): constrain degrades to a no-op
    refs = [np.asarray(jax.jit(lambda y, c=c: decode(y, phi, k, c))(y))
            for c in cfgs]

    # chunk-sharded: the chunk dim rides the model axis (DESIGN.md §4/§9).
    # Rows are decoded independently, but per-layout GEMM blocking may
    # round differently — allclose, not bitwise.
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    ysh = jax.device_put(y, NamedSharding(mesh, P("model", None)))
    with jax.set_mesh(mesh):
        for c, ref in zip(cfgs, refs):
            got = jax.jit(lambda y, c=c: decode(y, phi, k, c))(ysh)
            assert len(got.sharding.device_set) == 8, (c.algorithm,
                                                       got.sharding)
            assert np.allclose(np.asarray(got), ref, atol=1e-2), (
                c.algorithm, np.abs(np.asarray(got) - ref).max())

    # end-to-end reconstruct_chunks under the mesh matches off-mesh
    ob = OBCSAAConfig(chunk=512, measure=256, topk=32, biht_iters=8,
                      spmd_topk=True, phi_seed=5)
    mags = jnp.ones((n,))
    ref_flat = np.asarray(jax.jit(
        lambda y: reconstruct_chunks(ob, y, mags, phi))(y))
    with jax.set_mesh(mesh):
        got_flat = np.asarray(jax.jit(
            lambda y: reconstruct_chunks(ob, y, mags, phi))(ysh))
    assert np.allclose(got_flat, ref_flat, atol=1e-2), np.abs(
        got_flat - ref_flat).max()
    print("SHARDED_DECODE_OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    r = _run(SHARDED_SCRIPT)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "SHARDED_DECODE_OK" in r.stdout
