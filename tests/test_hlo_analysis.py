"""Unit tests for the dry-run HLO collective analyzer (trip-count scaling).

Uses a synthetic HLO text — no 512-device mesh needed, so this stays in the
default 1-device test environment.
"""
import textwrap

from repro.launch.dryrun import (_computation_multipliers,
                                 _split_computations, parse_collective_bytes)

HLO = textwrap.dedent("""\
    HloModule jit_step, is_scheduled=true

    %inner_body.1 (arg: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
      %ar.1 = f32[8,4]{1,0} all-reduce(f32[8,4]{1,0} %x.1), replica_groups={}
      ROOT %t.1 = (s32[], f32[8,4]) tuple(%i.1, %ar.1)
    }

    %inner_cond.1 (arg: (s32[], f32[8,4])) -> pred[] {
      ROOT %lt.1 = pred[] compare(%i.2, %c.2), direction=LT
    }

    %outer_body.2 (arg: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
      %w.2 = (s32[], f32[8,4]) while(%tup.2), condition=%inner_cond.1, body=%inner_body.1, backend_config={"known_trip_count":{"n":"5"}}
      %ag.2 = f32[16,4]{1,0} all-gather(f32[8,4]{1,0} %y.2), dimensions={0}
      ROOT %t.2 = (s32[], f32[8,4]) tuple(%i.3, %z.2)
    }

    %outer_cond.2 (arg: (s32[], f32[8,4])) -> pred[] {
      ROOT %lt.2 = pred[] compare(%i.4, %c.4), direction=LT
    }

    ENTRY %main.3 (p0: f32[8,4]) -> f32[8,4] {
      %w.3 = (s32[], f32[8,4]) while(%tup.3), condition=%outer_cond.2, body=%outer_body.2, backend_config={"known_trip_count":{"n":"3"}}
      %cp.3 = f32[8,4]{1,0} collective-permute(f32[8,4]{1,0} %q.3), source_target_pairs={{0,1}}
      ROOT %r.3 = f32[8,4]{1,0} copy(%res.3)
    }
    """)


def test_split_computations():
    comps, entry = _split_computations(HLO)
    assert entry == "main.3"
    assert set(comps) == {"inner_body.1", "inner_cond.1", "outer_body.2",
                          "outer_cond.2", "main.3"}


def test_multipliers_nested_whiles():
    comps, entry = _split_computations(HLO)
    mult = _computation_multipliers(comps, entry)
    assert mult["main.3"] == 1
    assert mult["outer_body.2"] == 3
    assert mult["inner_body.1"] == 15        # 3 x 5


def test_collective_bytes_scaled():
    res = parse_collective_bytes(HLO)
    f32_8x4 = 8 * 4 * 4
    # all-reduce in inner body: 15 executions, wire = 2x result each
    assert res["all-reduce"]["count"] == 15
    assert res["all-reduce"]["wire_bytes"] == 15 * 2 * f32_8x4
    # all-gather in outer body: 3 executions; result 16x4 f32
    assert res["all-gather"]["count"] == 3
    assert res["all-gather"]["wire_bytes"] == 3 * 16 * 4 * 4
    # collective-permute in entry: once
    assert res["collective-permute"]["count"] == 1
    assert res["total_count"] == 19
