"""Model-zoo regression tier (DESIGN.md §14).

Every architecture in ``repro.configs`` must survive one sharded FL round:
real per-worker gradients of the real smoke model, chunked and fed through
the shard_map'd compress → packed MAC → decode → update pipeline of
``repro.engine.zoo``, with a finite Theorem-1 ErrorBudget. The in-process
tier runs on the single-device host mesh (same shard_map code path, unit
worker federation); the 8-device subprocess test checks the sharded round
is BITWISE equal to the single-device reference oracle — surrogate-
gradient, real-gradient, and 3-round-chain variants."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_MODULES, InputShape, get_smoke_config
from repro.core.obcsaa import OBCSAAConfig
from repro.core.sparsify import flatten_pytree
from repro.engine.zoo import build_zoo_round
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ZOO_OB = dict(chunk=256, measure=64, topk=16, biht_iters=3,
              recon_alg="iht", spmd_topk=True, packed=True,
              bisect_iters=16)


def _make_batch(model, B=2, S=24, seed=0):
    """Materialise small concrete inputs from the model's input_specs."""
    cfg = model.cfg
    if cfg.family == "vlm":
        S = cfg.num_image_tokens + 8
    specs = model.input_specs(InputShape("zoo_smoke", S, B, "train"))
    key = jax.random.PRNGKey(seed)
    batch = {}
    for name in sorted(specs):
        sd = specs[name]
        key, k = jax.random.split(key)
        if jnp.issubdtype(sd.dtype, jnp.integer):
            batch[name] = jax.random.randint(k, sd.shape, 0,
                                             cfg.vocab_size, sd.dtype)
        else:
            batch[name] = (0.05 * jax.random.normal(k, sd.shape)
                           ).astype(sd.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_zoo_smoke_round(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _make_batch(model)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gflat, _ = flatten_pytree(grads)
    D = int(gflat.shape[0])

    mesh = make_host_mesh()
    zr = build_zoo_round(OBCSAAConfig(**ZOO_OB), D, mesh)
    assert zr.U == 1 and zr.n_chunks * zr.ob.chunk >= D
    psh = zr.shard_params(zr.chunk_params(params))
    gsh = zr.chunk_worker_grads(gflat[None])
    p2, st = zr.round_from_grads(psh, gsh, 0, jax.random.PRNGKey(1),
                                 1e-4, 10.0, 0.1)

    p2 = np.asarray(p2)
    assert p2.shape == (zr.n_chunks, zr.ob.chunk)
    assert np.isfinite(p2).all(), arch
    assert not np.array_equal(p2, np.asarray(psh)), \
        f"{arch}: round left parameters untouched"
    assert int(st.n_scheduled) == 1
    assert np.isfinite(float(st.ghat_norm)) and float(st.ghat_norm) > 0
    assert st.budget is not None
    for name, term in zip(st.budget._fields, st.budget):
        assert np.isfinite(np.asarray(term)).all(), (arch, name)
    # the updated flat vector round-trips out of the chunk layout
    flat2 = zr.unchunk(p2)
    assert flat2.shape == (D,) and np.isfinite(flat2).all()


SCRIPT_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.obcsaa import OBCSAAConfig
    from repro.engine.zoo import build_zoo_round

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ob = OBCSAAConfig(chunk=256, measure=64, topk=16, biht_iters=3,
                      recon_alg="iht", spmd_topk=True, packed=True,
                      bisect_iters=16)
    D = 16000                      # pads to 64 chunks, 8 per device
    zr = build_zoo_round(ob, D, mesh)
    assert (zr.U, zr.n_model, zr.n_local) == (4, 2, 8)
    key = jax.random.PRNGKey(7)
    flat = jax.random.normal(jax.random.PRNGKey(1), (D,), jnp.float32)
    chunked = zr.chunk_params(flat)
    psh = zr.shard_params(chunked)

    # surrogate-gradient round (the >=1B bench path)
    p2, st = zr.round_gen(psh, 0, key, 1e-4, 10.0, 0.1)
    r2, rst = zr.reference_round(chunked, 0, key, 1e-4, 10.0, 0.1)
    assert np.array_equal(np.asarray(p2), np.asarray(r2)), "gen round"
    assert np.array_equal(np.asarray(st.ghat_norm), np.asarray(rst.ghat_norm))
    assert all(np.isfinite(np.asarray(x)).all() for x in st.budget)

    # real-gradient round (the zoo smoke-tier path), U = 4 workers
    grads = jax.random.normal(jax.random.PRNGKey(2), (zr.U, D), jnp.float32)
    gsh = zr.chunk_worker_grads(grads)
    p3, _ = zr.round_from_grads(psh, gsh, 1, key, 1e-4, 10.0, 0.1)
    gref = jnp.pad(grads, ((0, 0), (0, zr.D_pad - D))).reshape(
        zr.U, zr.n_chunks, ob.chunk)
    r3, _ = zr.reference_round(chunked, 1, key, 1e-4, 10.0, 0.1, grads=gref)
    assert np.array_equal(np.asarray(p3), np.asarray(r3)), "grads round"

    # 3 chained rounds stay on-sharding and stay bitwise
    p4, stats = zr.run_rounds(psh, 3, key=key, noise_var=1e-4, p_max=10.0,
                              lr=0.1)
    rc = chunked
    for t in range(3):
        rc, _ = zr.reference_round(rc, t, key, 1e-4, 10.0, 0.1)
    assert np.array_equal(np.asarray(p4), np.asarray(rc)), "3-round chain"
    assert len(stats) == 3
    print("OK")
""")


@pytest.mark.slow
def test_zoo_sharded_round_bitwise_parity_8dev():
    """shard_map'd zoo round on a 4 workers x 2 model shards mesh ==
    single-device reference, bit for bit (packed int32 uplink + shared
    full-noise draw; DESIGN.md §14)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT_PARITY], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
