"""Prefill/forward vs step-by-step decode consistency — the serving path
computes the same function as the training forward (per architecture family,
including MLA's absorbed-latent decode and Mamba2's recurrent decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

ARCHS = ["starcoder2-15b", "gemma2-2b", "minicpm3-4b", "mamba2-2.7b",
         "zamba2-7b", "mixtral-8x22b", "whisper-base", "internvl2-1b",
         "gemma3-27b", "deepseek-v2-lite-16b"]

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")   # tight comparison
    if cfg.moe is not None:
        # capacity dropping is batch-size dependent by design; remove it so
        # prefill (T=B*S) and decode (T=B) compute the same function
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.02 * rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            0.02 * rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    full_logits = model.forward(params, batch, remat=False)
    offset = 0
    if cfg.family == "vlm":
        # serve path: prefill the image prefix into the cache, then decode
        # the text tokens at post-image positions — must reproduce the
        # text slice of the full forward
        from repro.launch.steps import make_seeded_prefill
        n_img = cfg.num_image_tokens
        seeded = make_seeded_prefill(model, n_img + S)
        _, cache, offset = seeded(
            params, {"tokens": tokens[:, :0],
                     "image_embeds": batch["image_embeds"]})
        assert offset == n_img
        full_logits = full_logits[:, n_img:]
    else:
        # step-by-step decode over the same tokens
        cache = model.init_cache(B, S)
        if cfg.family == "audio":
            from repro.models import encdec
            enc = encdec.encode(params, cfg, batch["frames"])
            cache = encdec.seed_cross_cache(params, cfg, cache, enc)
    dec = jax.jit(model.decode_step)
    outs = []
    for pos in range(S):
        logits, cache = dec(params, cache, tokens[:, pos:pos + 1],
                            jnp.int32(offset + pos))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    a = np.asarray(full_logits, np.float32)
    d = np.asarray(dec_logits, np.float32)
    # same prediction everywhere, logits close
    np.testing.assert_array_equal(a.argmax(-1), d.argmax(-1))
    np.testing.assert_allclose(a, d, rtol=2e-2, atol=2e-2)
