"""repro.sched — batched P2 solvers vs the NumPy oracle (DESIGN.md §10).

Parity contracts:
- batched ADMM == reference ``admm_solve`` per instance over B ≥ 64 random
  instances (β exact, R_t within float32 tolerance);
- vectorized greedy == loop greedy bit-for-bit on the schedule (β and b_t
  are picks from the same cap array) and greedy == enumeration for equal
  K_i at U ≤ 12;
- the Pallas prefix kernel == the jnp sweep bit-for-bit in interpret mode
  (full-extent tiles under jit, the production path) and within float
  tolerance for the tiled segmented path;
- scenario trajectories keep the Rayleigh marginal and the Gauss-Markov
  autocorrelation;
- ``BatchedProblem`` is a pytree whose constants are static: fresh channel
  draws never retrace the jitted solvers.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.theory import AnalysisConstants
from repro.kernels.prefix_eval import prefix_eval
from repro.sched import (BatchedProblem, Problem, ScenarioConfig,
                         SchedConfig, admm_solve, admm_solve_batched,
                         enumerate_solve, greedy_solve, greedy_solve_batched,
                         list_schedulers, schedule)
from repro.sched.greedy import pack_coefs, prefix_sweep
from repro.sched.reference import _rt, greedy_prefix_bound, optimal_bt
from repro.sched.scenario import bessel_j0, generate, generate_fades


def make_problem(U=6, seed=0, rho1=200.0, G=1.0, p_max=10.0):
    rng = np.random.default_rng(seed)
    return Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                   k_weights=np.full(U, 3000.0), p_max=p_max,
                   noise_var=1e-4, D=50890, S=1000, kappa=1000,
                   const=AnalysisConstants(rho1=rho1, G=G))


def random_problems(n, U, seed=0, equal_k=True):
    rng = np.random.default_rng(seed)
    const = AnalysisConstants(rho1=200.0, G=1.0)
    probs = []
    for _ in range(n):
        k = (np.full(U, 3000.0) if equal_k
             else rng.uniform(1000.0, 5000.0, size=U))
        probs.append(Problem(h=np.abs(rng.normal(size=U)) + 1e-3,
                             k_weights=k, p_max=10.0, noise_var=1e-4,
                             D=50890, S=1000, kappa=1000, const=const))
    return probs


# --- per-worker power budgets (paper eq. 10: P_i^Max) -----------------------------

def test_per_worker_p_max_caps():
    prob = make_problem(U=4, p_max=np.array([10.0, 10.0, 1e-6, 10.0]))
    beta = np.ones(4)
    # worker 2's tiny budget pins b_t to its boundary
    bt = optimal_bt(prob, beta)
    assert np.isclose(bt, prob.caps()[2])
    p = (prob.k_weights * bt / prob.h) ** 2
    assert (p <= prob.p_max_vec * (1 + 1e-9)).all()


def test_scalar_p_max_broadcast_matches_vector():
    ps, pv = make_problem(seed=3), make_problem(
        seed=3, p_max=np.full(6, 10.0))
    for solver in (enumerate_solve, admm_solve, greedy_solve):
        bs, bts, rs = solver(ps)
        bv, btv, rv = solver(pv)
        assert np.array_equal(bs, bv) and bts == btv and rs == rv


def test_admm_respects_per_worker_budgets():
    rng = np.random.default_rng(7)
    prob = make_problem(U=16, seed=7,
                        p_max=rng.uniform(0.5, 20.0, size=16))
    beta, bt, r = admm_solve(prob)
    assert np.isfinite(r) and bt > 0
    p = (prob.k_weights * beta * bt / prob.h) ** 2
    assert (p <= prob.p_max_vec * (1 + 1e-6)).all()


# --- batched ADMM vs the float64 oracle -------------------------------------------

@pytest.mark.parametrize("equal_k", [True, False])
def test_batched_admm_matches_numpy_per_instance(equal_k):
    """B = 64 random instances in ONE device call == 64 scalar solves."""
    probs = random_problems(64, U=8, seed=11, equal_k=equal_k)
    bp = BatchedProblem.from_problems(probs)
    beta_b, bt_b, r_b = jax.block_until_ready(admm_solve_batched(bp))
    mismatched = 0
    for i, p in enumerate(probs):
        beta_n, bt_n, r_n = admm_solve(p)
        mismatched += not np.array_equal(np.asarray(beta_b[i]), beta_n)
        # float32 batched vs float64 oracle: R_t parity is tolerance-based
        assert abs(float(r_b[i]) - r_n) / r_n < 1e-4, i
        assert abs(float(bt_b[i]) - bt_n) / max(bt_n, 1e-12) < 1e-4, i
    # β decisions may flip only on numerically marginal workers
    assert mismatched <= 1


@pytest.mark.parametrize("equal_k", [True, False])
def test_inner_budget_16_equals_50_bitwise(equal_k):
    """The step-1 projected gradient steps with 1/Lipschitz and reaches
    its float32 fixed point in ≲12 iterations: the default device budget
    (16) and the reference's 50 yield bit-identical schedules."""
    probs = random_problems(48, U=16, seed=31, equal_k=equal_k)
    bp = BatchedProblem.from_problems(probs)
    out16 = admm_solve_batched(bp, SchedConfig(inner_iters=16))
    out50 = admm_solve_batched(bp, SchedConfig(inner_iters=50))
    assert bool(jnp.all(out16[0] == out50[0]))
    assert bool(jnp.all(out16[1] == out50[1]))


def test_batched_admm_feasible_at_large_u():
    probs = random_problems(4, U=64, seed=5)
    bp = BatchedProblem.from_problems(probs)
    beta, bt, r = admm_solve_batched(bp)
    assert beta.shape == (4, 64) and bool(jnp.all(jnp.isfinite(r)))
    p = (bp.k_weights * beta * bt[:, None] / bp.h) ** 2
    assert bool(jnp.all(p <= bp.p_max * (1 + 1e-5)))


def test_admm_polish_early_exit_bound():
    """The greedy prefix bound is a true lower bound on what the polish
    can reach from a prefix-family schedule (equal K ⇒ optimum)."""
    for seed in range(4):
        prob = make_problem(U=10, seed=seed)
        _, _, r_admm = admm_solve(prob)
        assert r_admm <= greedy_prefix_bound(prob) * (1 + 1e-6)


# --- greedy: vectorized == loop, exact for equal K --------------------------------

def test_vectorized_greedy_matches_loop_bitwise():
    """β and b_t are picks from the same cap array — bit-for-bit; R_t is
    recomputed arithmetic, compared at float32 tolerance."""
    probs = random_problems(50, U=24, seed=2, equal_k=False)
    bp = BatchedProblem.from_problems(probs)
    beta_v, bt_v, r_v = greedy_solve_batched(bp)
    for i, p in enumerate(probs):
        beta_l, bt_l, r_l = greedy_solve(p)
        assert np.array_equal(np.asarray(beta_v[i]), beta_l), i
        assert np.isclose(float(bt_v[i]), bt_l, rtol=1e-6), i
        assert np.isclose(float(r_v[i]), r_l, rtol=1e-5), i


@pytest.mark.parametrize("U", [6, 10, 12])
def test_batched_greedy_equals_enumeration_equal_k(U):
    """Equal K_i ⇒ the prefix optimum IS the global optimum (U ≤ 12)."""
    for seed in range(3):
        prob = make_problem(U=U, seed=seed + 20)
        _, _, r_enum = enumerate_solve(prob)
        beta, bt, r = greedy_solve_batched(BatchedProblem.single(prob))
        assert np.isclose(float(r[0]), r_enum, rtol=1e-5), (U, seed)
        # and the reported R_t is consistent with the oracle objective
        r_check = _rt(prob, np.asarray(beta[0], np.float64), float(bt[0]))
        assert np.isclose(r_check, r_enum, rtol=1e-5)


# --- Pallas prefix kernel ----------------------------------------------------------

def _sorted_inputs(B=4, U=8192, seed=0):
    h = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (B, U))) + 1e-3
    bp = BatchedProblem.from_arrays(
        h, 3000.0, 10.0, 1e-4, D=508900, S=1000, kappa=1000,
        const=AnalysisConstants(rho1=200.0, G=1.0))
    caps = bp.caps()
    order = jnp.argsort(-caps, axis=-1)
    return (jnp.take_along_axis(caps, order, -1),
            jnp.take_along_axis(bp.k_weights, order, -1), pack_coefs(bp)), bp


def test_prefix_kernel_bitwise_vs_jnp_interpret():
    """Full-extent interpret tiles under jit == the jnp sweep bit-for-bit
    (the production path jits both; DESIGN.md §10 tiling policy)."""
    (caps_s, k_s, coefs), _ = _sorted_inputs()
    r_jnp = jax.jit(prefix_sweep)(caps_s, k_s, coefs)
    r_ker = jax.jit(lambda a, b, c: prefix_eval(a, b, c, interpret=True))(
        caps_s, k_s, coefs)
    assert bool(jnp.all(r_jnp == r_ker))


def test_prefix_kernel_tiled_segmented_carry():
    """The tiled path (segmented ΣK carry across U tiles) agrees to float
    tolerance and picks the same prefix, including non-divisible U."""
    (caps_s, k_s, coefs), _ = _sorted_inputs(B=3, U=1000, seed=1)
    r_jnp = jax.jit(prefix_sweep)(caps_s, k_s, coefs)
    r_tiled = prefix_eval(caps_s, k_s, coefs, interpret=True,
                          tiles=(2, 128))
    assert r_tiled.shape == r_jnp.shape
    assert bool(jnp.allclose(r_jnp, r_tiled, rtol=1e-5))
    assert bool(jnp.all(jnp.argmin(r_jnp, -1) == jnp.argmin(r_tiled, -1)))


def test_greedy_kernel_path_matches_jnp_path():
    _, bp = _sorted_inputs(B=4, U=4096, seed=2)
    beta_j, bt_j, _ = greedy_solve_batched(bp)
    beta_k, bt_k, _ = greedy_solve_batched(
        bp, SchedConfig(use_kernel=True, interpret=True))
    assert bool(jnp.all(beta_j == beta_k)) and bool(jnp.all(bt_j == bt_k))


# --- scenario generator -------------------------------------------------------------

def test_scenario_rayleigh_marginal_and_autocorr():
    cfg = ScenarioConfig(rounds=400, cells=4, workers=64, corr=0.9)
    g = generate_fades(cfg, jax.random.PRNGKey(1))
    assert g.shape == (400, 4, 64)
    mag = jnp.abs(g)
    # CN(0,1) fades: E|g|² = 1, E|g| = √π/2 (Rayleigh σ = 1/√2)
    assert abs(float(jnp.mean(mag ** 2)) - 1.0) < 0.05
    assert abs(float(jnp.mean(mag)) - np.sqrt(np.pi) / 2) < 0.02
    gf = g.reshape(cfg.rounds, -1)
    for lag in (1, 3):
        ac = float(jnp.mean(jnp.real(gf[lag:] * jnp.conj(gf[:-lag]))))
        assert abs(ac - cfg.rho ** lag) < 0.05, lag


def test_scenario_jakes_and_iid_rho():
    jakes = ScenarioConfig(model="jakes", doppler_hz=10.0, slot_s=0.01)
    assert np.isclose(jakes.rho, bessel_j0(2 * np.pi * 0.1), atol=1e-12)
    assert np.isclose(bessel_j0(1.0), 0.7651977, atol=2e-7)
    assert np.isclose(bessel_j0(5.0), -0.1775968, atol=2e-7)
    assert ScenarioConfig(model="iid").rho == 0.0
    with pytest.raises(ValueError):
        _ = ScenarioConfig(model="nope").rho


def test_scenario_magnitudes_clamped_and_shadowed():
    cfg = ScenarioConfig(rounds=8, cells=2, workers=16, shadowing_db=8.0,
                         cell_radius=1.0)
    h = generate(cfg, jax.random.PRNGKey(3))
    assert h.shape == (8, 2, 16)
    assert float(h.min()) >= cfg.h_min


# --- registry + pytree/jit behaviour ------------------------------------------------

def test_registry_dispatch_and_single_lift():
    assert {"all", "enum", "admm", "greedy", "admm_batched",
            "greedy_batched"} <= set(list_schedulers())
    prob = make_problem(seed=4)
    with pytest.raises(ValueError, match="unknown scheduling method"):
        schedule(prob, "nope")
    beta_ref, bt_ref, r_ref = schedule(prob, "greedy")
    beta_b, bt_b, r_b = schedule(prob, "greedy_batched")
    assert isinstance(beta_b, np.ndarray) and isinstance(bt_b, float)
    assert np.array_equal(beta_ref, beta_b)
    assert np.isclose(bt_ref, bt_b, rtol=1e-6)
    # batched problem through a reference entry: per-instance loop
    bp = BatchedProblem.from_problems(random_problems(3, U=6, seed=9))
    beta, bt, r = schedule(bp, "greedy")
    assert beta.shape == (3, 6) and bt.shape == (3,)


def test_schedule_all_matches_power_boundary():
    prob = make_problem(seed=6)
    beta, bt, _ = schedule(prob, "all")
    assert beta.sum() == prob.U
    assert np.isclose(bt, optimal_bt(prob, np.ones(prob.U)), rtol=1e-12)


def test_batched_problem_no_recompile_on_new_channels():
    """Static aux (D/S/κ/const) + array leaves ⇒ one trace per shape."""
    traces = []

    @jax.jit
    def solve(prob):
        traces.append(1)
        return prefix_sweep(prob.h, prob.k_weights, pack_coefs(prob))

    const = AnalysisConstants()
    for seed in range(3):
        h = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (8, 16))) \
            + 1e-3
        bp = BatchedProblem.from_arrays(h, 3000.0, 10.0, 1e-4, D=50890,
                                        S=1000, kappa=1000, const=const)
        solve(bp).block_until_ready()
    assert len(traces) == 1
    # the public solvers are jitted with the same pytree contract
    for seed in range(3):
        h = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (4, 8))) \
            + 1e-3
        bp = BatchedProblem.from_arrays(h, 3000.0, 10.0, 1e-4, D=50890,
                                        S=1000, kappa=1000, const=const)
        greedy_solve_batched(bp)
        admm_solve_batched(bp)


def test_scheduled_round_ctx_smoke():
    """launch/steps.py device-resident scheduling path (DESIGN.md §10)."""
    from jax.sharding import Mesh
    from repro.configs.base import TrainConfig
    from repro.launch.steps import make_scheduled_round_ctx

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    tcfg = TrainConfig()
    ctx_fn = make_scheduled_round_ctx(mesh, tcfg, D=50890)
    ctx = ctx_fn(0)
    U = 1
    assert ctx["beta"].shape == (U,) and ctx["h"].shape == (U,)
    assert float(ctx["b_t"]) > 0
    assert set(ctx) == {"h", "beta", "b_t", "key"}
