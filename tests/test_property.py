"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # offline container without hypothesis: run the same properties over a
    # deterministic example sweep instead of skipping the module
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.power_control import feasible, max_bt, tx_power
from repro.core.quantize import pack_bits, sign_pm1, unpack_bits
from repro.core.sparsify import topk_sparsify, topk_sparsify_chunked
from repro.models.layers import chunked_cross_entropy
from repro.models.registry import cross_entropy

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 63), st.integers(0, 2 ** 31 - 1))
def test_topk_keeps_exactly_k_and_largest(k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    sx, mask = topk_sparsify(x, k)
    assert int(mask.sum()) == k
    kept_min = float(jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf)))
    dropped_max = float(jnp.max(jnp.where(mask, -jnp.inf, jnp.abs(x))))
    assert kept_min >= dropped_max - 1e-7
    np.testing.assert_array_equal(np.asarray(sx != 0), np.asarray(mask))


@given(st.integers(1, 15), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_topk_chunked_per_chunk_budget(k, nc, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (nc * 32,))
    _, mask = topk_sparsify_chunked(x, min(k, 32), 32)
    per_chunk = np.asarray(mask).reshape(nc, 32).sum(axis=1)
    assert (per_chunk == min(k, 32)).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_sign_never_zero(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    x = x.at[:7].set(0.0)
    s = sign_pm1(x)
    assert bool(jnp.all(jnp.abs(s) == 1.0))


@given(st.integers(1, 16).map(lambda n: n * 8), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    s = sign_pm1(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    assert np.array_equal(np.asarray(unpack_bits(pack_bits(s), n)),
                          np.asarray(s))


@given(st.integers(2, 12), st.integers(0, 2 ** 31 - 1),
       st.floats(0.1, 100.0))
def test_max_bt_is_tight_and_feasible(u, seed, pmax):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(np.abs(rng.normal(size=u)) + 1e-3, jnp.float32)
    kw = jnp.asarray(rng.uniform(1, 100, u), jnp.float32)
    beta = jnp.asarray((rng.random(u) > 0.3).astype(np.float32))
    if float(beta.sum()) == 0:
        beta = beta.at[0].set(1.0)
    bt = max_bt(beta, kw, h, pmax)
    assert bool(feasible(beta, kw, bt, h, pmax))
    p = tx_power(beta, kw, bt, h)
    assert np.isclose(float(jnp.max(p)), pmax, rtol=1e-4)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_chunked_ce_equals_dense_ce(b, nb, seed):
    """The chunked-CE memory optimization is mathematically exact."""
    S, V, d = nb * 16, 37, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, S, d))
    emb = jax.random.normal(ks[1], (V, d))
    tgt = jax.random.randint(ks[2], (b, S), 0, V)
    dense = cross_entropy(x @ emb.T, tgt)
    chunked = chunked_cross_entropy(x, tgt, embedding=emb, seq_chunk=16)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)
