"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # offline container without hypothesis: run the same properties over a
    # deterministic example sweep instead of skipping the module
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.power_control import feasible, max_bt, tx_power
from repro.core.quantize import pack_bits, sign_pm1, unpack_bits
from repro.core.sparsify import topk_sparsify, topk_sparsify_chunked
from repro.dist.flat_layout import FlatShardLayout
from repro.kernels.sign import pack_signs, unpack_signs
from repro.models.layers import chunked_cross_entropy
from repro.models.registry import cross_entropy

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


class _StubMesh:
    """Just enough mesh for ``FlatShardLayout.build``: the layout consumes
    only ``dict(mesh.shape)`` (via ``dist.sharding._axis_sizes`` after
    ``compat._unwrap``), so property tests can sweep mesh shapes without
    allocating devices."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


@given(st.integers(1, 63), st.integers(0, 2 ** 31 - 1))
def test_topk_keeps_exactly_k_and_largest(k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    sx, mask = topk_sparsify(x, k)
    assert int(mask.sum()) == k
    kept_min = float(jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf)))
    dropped_max = float(jnp.max(jnp.where(mask, -jnp.inf, jnp.abs(x))))
    assert kept_min >= dropped_max - 1e-7
    np.testing.assert_array_equal(np.asarray(sx != 0), np.asarray(mask))


@given(st.integers(1, 15), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_topk_chunked_per_chunk_budget(k, nc, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (nc * 32,))
    _, mask = topk_sparsify_chunked(x, min(k, 32), 32)
    per_chunk = np.asarray(mask).reshape(nc, 32).sum(axis=1)
    assert (per_chunk == min(k, 32)).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_sign_never_zero(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    x = x.at[:7].set(0.0)
    s = sign_pm1(x)
    assert bool(jnp.all(jnp.abs(s) == 1.0))


@given(st.integers(1, 16).map(lambda n: n * 8), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    s = sign_pm1(jax.random.normal(jax.random.PRNGKey(seed), (n,)))
    assert np.array_equal(np.asarray(unpack_bits(pack_bits(s), n)),
                          np.asarray(s))


@given(st.integers(2, 12), st.integers(0, 2 ** 31 - 1),
       st.floats(0.1, 100.0))
def test_max_bt_is_tight_and_feasible(u, seed, pmax):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(np.abs(rng.normal(size=u)) + 1e-3, jnp.float32)
    kw = jnp.asarray(rng.uniform(1, 100, u), jnp.float32)
    beta = jnp.asarray((rng.random(u) > 0.3).astype(np.float32))
    if float(beta.sum()) == 0:
        beta = beta.at[0].set(1.0)
    bt = max_bt(beta, kw, h, pmax)
    assert bool(feasible(beta, kw, bt, h, pmax))
    p = tx_power(beta, kw, bt, h)
    assert np.isclose(float(jnp.max(p)), pmax, rtol=1e-4)


@given(st.integers(0, 2), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
def test_flat_layout_chunk_unchunk_roundtrip(mp_exp, gran, cw, seed):
    """The model-major sharded-flat layout (dist.flat_layout, DESIGN.md
    §16/§17) is lossless and gran-aligned over randomized parameter
    structures and mesh shapes:

    - ``master_to_tree(tree_to_master(p))`` returns every leaf bitwise;
    - ``n_half`` is a whole multiple of ``gran`` (every worker owns whole
      chunk rows) and ``n_chunks == mp * n_half``;
    - section padding is exactly zero;
    - the device-local ``section_to_tree``/``tree_to_section`` pair
      round-trips each m-section bitwise — the invariant that makes
      layout conversion zero-communication in the round."""
    rng = np.random.default_rng(seed)
    mp, chunk = 2 ** mp_exp, 16 * cw
    shapes, params = {}, {}
    for i in range(int(rng.integers(1, 5))):
        r, c = int(rng.integers(1, 5)), int(rng.integers(1, 5))
        shape = ((mp * r, c), (c, mp * r), (mp * r,))[int(rng.integers(3))]
        shapes[f"w{i}"] = jax.ShapeDtypeStruct(shape, jnp.float32)
        params[f"w{i}"] = rng.standard_normal(shape).astype(np.float32)
    layout = FlatShardLayout.build(shapes, _StubMesh(data=gran, model=mp),
                                   chunk=chunk, gran=gran)
    assert layout.n_half % gran == 0
    assert layout.n_chunks == mp * layout.n_half
    assert layout.D == sum(v.size for v in params.values())
    assert layout.D_pad >= layout.D

    master = layout.tree_to_master(params)
    assert master.shape == (layout.n_chunks, chunk)
    back = layout.master_to_tree(master)
    for k in params:
        assert np.array_equal(np.asarray(back[k]), params[k]), k

    sections = np.asarray(master).reshape(mp, layout.n_half * chunk)
    assert (sections[:, layout.sec_elems:] == 0).all()   # pad is zero
    for m in range(mp):
        sect = master.reshape(mp, layout.n_half, chunk)[m]
        again = layout.tree_to_section(layout.section_to_tree(sect))
        assert np.array_equal(np.asarray(again), np.asarray(sect)), m


def test_flat_layout_indivisible_leaf_message():
    """A leaf with no model-divisible dim fails at build, naming the
    leaf (DESIGN.md §16)."""
    shapes = {"odd": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    with pytest.raises(ValueError, match=r"odd.*divisible by the "
                                         r"model-axis size 2"):
        FlatShardLayout.build(shapes, _StubMesh(data=1, model=2), chunk=8)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_signs_roundtrip_with_signed_zeros(rows, words, seed):
    """The 32-per-uint32 packed codec (kernels.sign, DESIGN.md §13):
    ``unpack_signs(pack_signs(s)) == s`` bitwise on ±1 symbols, and the
    fused sign+pack on RAW values agrees with sign-then-pack — including
    x == +0.0 and x == -0.0, both of which the repo-wide sign convention
    maps to +1 (the ``x >= 0`` predicate is signed-zero-blind)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, 32 * words)).astype(np.float32)
    flat = x.reshape(-1)
    idx = rng.choice(flat.size, size=min(8, flat.size), replace=False)
    flat[idx[0::2]] = 0.0
    flat[idx[1::2]] = -0.0
    x = jnp.asarray(flat.reshape(x.shape))
    s = sign_pm1(x)
    packed = pack_signs(x)
    assert np.array_equal(np.asarray(packed), np.asarray(pack_signs(s)))
    assert np.array_equal(np.asarray(unpack_signs(packed)), np.asarray(s))
    assert (np.asarray(s).reshape(-1)[idx] == 1.0).all()   # sign(±0) = +1


def test_pack_signs_misaligned_axis_message():
    """A sign axis that does not pack into whole uint32 words fails
    loudly with the offending length (DESIGN.md §13)."""
    with pytest.raises(ValueError, match=r"multiple of 32; got 40"):
        pack_signs(jnp.ones((2, 40)))


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_chunked_ce_equals_dense_ce(b, nb, seed):
    """The chunked-CE memory optimization is mathematically exact."""
    S, V, d = nb * 16, 37, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, S, d))
    emb = jax.random.normal(ks[1], (V, d))
    tgt = jax.random.randint(ks[2], (b, S), 0, V)
    dense = cross_entropy(x @ emb.T, tgt)
    chunked = chunked_cross_entropy(x, tgt, embedding=emb, seq_chunk=16)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)
