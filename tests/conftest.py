import os
import sys

# Smoke tests and benches must see 1 device (the dry-run sets 512 itself,
# in a separate process). Keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)

# Initialize the backend NOW with 1 device: test modules that import
# repro.launch.dryrun (which sets --xla_force_host_platform_device_count=512
# for its own subprocess usage) must not affect the already-locked device
# count of this test process.
_ = jax.devices()
