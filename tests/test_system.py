"""End-to-end system tests: the paper's FL loop on the MNIST MLP.

Validates the paper's central claim at test scale: OBCSAA learns, and its
accuracy approaches perfect aggregation; scheduling via ADMM matches
enumeration's behavior inside the loop.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.obcsaa import OBCSAAConfig
from repro.data import load_mnist, partition_workers
from repro.fl import FederatedTrainer, FLConfig
from repro.models.mlp_mnist import (init_mlp_mnist, mlp_mnist_accuracy,
                                    mlp_mnist_loss, param_dim)

U, K = 10, 300


@pytest.fixture(scope="module")
def setup():
    xtr, ytr, xte, yte = load_mnist()
    wx, wy = partition_workers(xtr, ytr, U, K, seed=0)
    worker_data = {"x": jnp.asarray(wx), "y": jnp.asarray(wy)}
    params0 = init_mlp_mnist(jax.random.PRNGKey(0))
    xte_j, yte_j = jnp.asarray(xte[:1000]), jnp.asarray(yte[:1000])

    @jax.jit
    def eval_fn(p):
        return (mlp_mnist_loss(p, xte_j, yte_j),
                mlp_mnist_accuracy(p, xte_j, yte_j))

    def loss_fn(p, data):
        return mlp_mnist_loss(p, data["x"], data["y"])

    return worker_data, params0, eval_fn, loss_fn


def make_trainer(setup, agg, scheduler="all", rounds=25):
    worker_data, params0, eval_fn, loss_fn = setup
    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
    cfg = FLConfig(aggregator=agg, scheduler=scheduler, rounds=rounds,
                   eval_every=rounds - 1, obcsaa=ob)
    return FederatedTrainer(cfg, loss_fn, params0, worker_data,
                            np.full(U, float(K)), eval_fn=eval_fn)


def test_paper_mlp_dimension():
    params = init_mlp_mnist(jax.random.PRNGKey(0))
    assert param_dim(params) == 50890   # paper §V: D = 50890


def test_perfect_aggregation_learns(setup):
    tr = make_trainer(setup, "perfect")
    logs = tr.run()
    assert logs[-1].accuracy > 0.85


def test_obcsaa_learns_and_approaches_perfect(setup):
    tr_p = make_trainer(setup, "perfect", rounds=30)
    tr_o = make_trainer(setup, "obcsaa", rounds=30)
    acc_p = tr_p.run()[-1].accuracy
    acc_o = tr_o.run()[-1].accuracy
    assert acc_o > 0.30                  # learning is happening
    assert acc_o > 0.3 * acc_p           # same order as perfect at this scale


def test_topk_aa_baseline_learns(setup):
    tr = make_trainer(setup, "topk_aa")
    logs = tr.run()
    assert logs[-1].accuracy > 0.5


@pytest.mark.parametrize("scheduler", ["admm", "greedy"])
def test_scheduled_obcsaa_runs(setup, scheduler):
    tr = make_trainer(setup, "obcsaa", scheduler=scheduler, rounds=6)
    logs = tr.run()
    assert np.isfinite(logs[-1].loss)
    assert 1 <= logs[-1].n_scheduled <= U


def test_loss_decreases_over_rounds(setup):
    worker_data, params0, eval_fn, loss_fn = setup
    ob = OBCSAAConfig(chunk=4096, measure=1024, topk=80, biht_iters=25)
    cfg = FLConfig(aggregator="obcsaa", scheduler="all", rounds=20,
                   eval_every=4, obcsaa=ob)
    tr = FederatedTrainer(cfg, loss_fn, params0, worker_data,
                          np.full(U, float(K)), eval_fn=eval_fn)
    logs = tr.run()
    assert logs[-1].loss < logs[0].loss
