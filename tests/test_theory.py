"""Closed-form convergence analytics (repro.theory, DESIGN.md §12).

The load-bearing claims:
- the ``ErrorBudget`` error terms sum — bitwise, in field order — to
  ``lemma1_error_bound`` (eq. 19), and the budget is monotone the way
  Remark 1 says: increasing in σ², decreasing in κ and S;
- the traced C(δ) matches the scalar eq. (46) on the valid range and
  returns +inf past δ = √2 − 1 instead of raising;
- the tuner's single broadcast evaluation over the candidate grid equals
  a per-candidate Python-loop reference, and its Pareto frontier is a
  true non-dominated set;
- the engine threads the budget as dense scan outputs (run_sweep
  ``rt_bound``/``budget`` per arm-round) with the measured-error probe
  matching a host-side recomputation, and the probe is measure-zero on
  the training trajectory when enabled/disabled.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.measurement import reconstruction_constant
from repro.core.obcsaa import OBCSAAConfig
from repro.engine import FLConfig, run_sweep
from repro.engine.core import perfect_aggregate, stacked_grads
from repro.fl import FederatedTrainer
from repro.theory import (AnalysisConstants, DELTA_MAX, ErrorBudget,
                          bt_term, delta_model, error_budget,
                          error_floor_asymptote, lemma1_error_bound,
                          pareto_mask, reconstruction_constant_traced,
                          rt_objective, theorem1_trajectory, tune_design)

U = 4
COMMON = dict(D=50890, S=13312, kappa=1040,
              k_weights=np.full(10, 3000.0), b_t=0.001, noise_var=1e-4)


# --- budget decomposition ---------------------------------------------------------

def test_budget_terms_sum_to_lemma1_bitwise():
    c = AnalysisConstants()
    b = error_budget(c, beta=np.ones(10), **COMMON)
    total = (b.quantization + b.dim_reduction + b.noise
             + b.reconstruction + b.sparsification)
    l1 = lemma1_error_bound(c, beta=np.ones(10), **COMMON)
    assert np.array_equal(np.asarray(total), np.asarray(l1))
    # every error source contributes a strictly positive share
    for f in ("quantization", "dim_reduction", "noise", "reconstruction",
              "sparsification"):
        assert float(getattr(b, f)) > 0.0, f
    # full participation -> no scheduling penalty; rt = 2L·bt
    assert float(b.scheduling) == 0.0
    assert float(b.rt()) == pytest.approx(
        2.0 * c.L * float(b.bt(c.L)), rel=1e-6)


def test_bound_monotone_in_sigma_and_sparsity():
    """Remark 1 + the σ² direction: the bound grows with noise and with
    the discarded fraction (D−κ)/D, shrinks with measurements S."""
    c = AnalysisConstants()
    beta = np.ones(10)

    def at(**kw):
        args = dict(COMMON, **kw)
        return float(lemma1_error_bound(c, beta=beta, **args))

    base = at()
    # total: strong contrast (f32 — a tiny σ² shift vanishes next to the
    # G² terms); the noise field itself is strictly monotone at any scale
    assert at(noise_var=10.0) > base >= at(noise_var=1e-8)
    n_lo = error_budget(c, beta=beta, **dict(COMMON, noise_var=1e-8)).noise
    n_hi = error_budget(c, beta=beta, **dict(COMMON, noise_var=1e-2)).noise
    assert float(n_hi) > float(n_lo) > 0.0
    assert at(kappa=520) > base > at(kappa=5200)        # larger (D−κ)/D
    assert at(S=6656) > base > at(S=26624)              # fewer measurements
    # scheduling exclusion penalty appears when β drops workers
    b_part = error_budget(c, beta=np.r_[np.ones(5), np.zeros(5)], **COMMON)
    assert float(b_part.scheduling) > 0.0


def test_theorem1_trajectory_converges_to_error_floor():
    c = AnalysisConstants(rho2=0.5)
    bt = 0.2
    traj = theorem1_trajectory(c, 5.0, jnp.full((3, 60), bt))
    assert traj.shape == (3, 60)
    floor = float(error_floor_asymptote(c, bt))
    # monotone decay onto the floor from above (Δ0 > floor)
    t0 = np.asarray(traj[0])
    assert np.all(np.diff(t0) <= 1e-6)
    assert t0[-1] == pytest.approx(floor, rel=1e-5)
    assert np.all(t0 >= floor - 1e-6)


def test_traced_recon_constant_matches_scalar_and_caps():
    deltas = [0.05, 0.2, 0.4]
    traced = np.asarray(reconstruction_constant_traced(np.array(deltas)))
    for d, t in zip(deltas, traced):
        assert t == pytest.approx(reconstruction_constant(d), rel=1e-5)
    bad = np.asarray(reconstruction_constant_traced(
        np.array([DELTA_MAX, 0.6, 1.5])))
    assert np.all(np.isinf(bad))


# --- tuner ------------------------------------------------------------------------

def test_vmapped_tuner_matches_python_loop_reference():
    """The tuner's one broadcast R_t evaluation over the (κ, S) grid ==
    looping scalar ``rt_objective`` calls per candidate."""
    c = AnalysisConstants(G=2.0)
    D, d_chunk = 50890, 4096
    kappas, measures = [20, 80, 320, 1280], [256, 1024]
    kw = np.full(U, 3000.0)
    res = tune_design(c, D=D, d_chunk=d_chunk, kappas=kappas,
                      measures=measures, decode_iters=[10], k_weights=kw,
                      noise_var=1e-4, b_t=0.001, calib=0.3)
    n_chunks = -(-D // d_chunk)
    for i in range(len(res["rt"])):
        k, s = int(res["kappa"][i]), int(res["measure"][i])
        d = float(delta_model(k, s, d_chunk, calib=0.3))
        assert d == pytest.approx(float(res["delta"][i]), rel=1e-6)
        if d >= DELTA_MAX:
            assert np.isinf(res["rt"][i])
            continue
        ref = rt_objective(c, D=D, S=n_chunks * s,
                           kappa=min(n_chunks * k, D),
                           beta=np.ones(U), k_weights=kw, b_t=0.001,
                           noise_var=1e-4, delta=d)
        assert float(ref) == pytest.approx(res["rt"][i], rel=1e-5), (k, s)


def test_tuner_pareto_frontier_is_nondominated():
    c = AnalysisConstants(G=2.0)
    res = tune_design(c, D=50890, d_chunk=4096,
                      kappas=[20, 80, 320, 1280], measures=[256, 1024],
                      decode_iters=[5, 25], k_weights=np.full(U, 3000.0),
                      noise_var=1e-4, b_t=0.001, calib=0.3,
                      max_symbols=13 * 1025)
    obj = np.stack([res["rt"], res["symbols"], res["flops"]], axis=1)
    mask = res["pareto"]
    assert mask.any()
    assert np.all(np.isfinite(obj[mask]))
    for i in np.flatnonzero(mask):        # no frontier point dominated
        dominated = np.any(
            np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1))
        assert not dominated
    # every dominated candidate has a frontier witness
    front = obj[mask]
    for i in np.flatnonzero(~mask & np.all(np.isfinite(obj), axis=1)):
        assert np.any(np.all(front <= obj[i], axis=1)
                      & np.any(front < obj[i], axis=1))
    # the budgeted best is feasible and within the symbol budget
    b = res["best"]
    assert np.isfinite(res["rt"][b]) and res["symbols"][b] <= 13 * 1025


def test_pareto_mask_basic():
    obj = np.array([[1.0, 1.0], [2.0, 2.0], [0.5, 3.0], [np.inf, 0.0]])
    assert list(pareto_mask(obj)) == [True, False, True, False]


def test_tuner_raises_when_budget_infeasible():
    """An unsatisfiable symbol budget must not silently select a grid
    corner (−1 or None both index numpy arrays without error) — the
    tuner refuses loudly."""
    c = AnalysisConstants(G=2.0)
    with pytest.raises(ValueError, match="RIP-feasible"):
        tune_design(c, D=50890, d_chunk=4096, kappas=[20, 80],
                    measures=[256, 1024], k_weights=np.full(U, 3000.0),
                    noise_var=1e-4, b_t=0.001, calib=0.3, max_symbols=10)


# --- engine integration -----------------------------------------------------------

@pytest.fixture(scope="module")
def task():
    """The synthetic regression task of tests/test_engine.py."""
    d_in, d_out, n = 24, 8, 16
    key = jax.random.PRNGKey(7)
    kw, kx, kn = jax.random.split(key, 3)
    w_star = jax.random.normal(kw, (d_in, d_out))
    x = jax.random.normal(kx, (U, n, d_in))
    y = jnp.einsum("ukd,dc->ukc", x, w_star) \
        + 0.01 * jax.random.normal(kn, (U, n, d_out))
    wd = {"x": x, "y": y}
    params0 = {"w": jnp.zeros((d_in, d_out))}

    def loss_fn(p, data):
        pred = data["x"] @ p["w"]
        return jnp.mean((pred - data["y"]) ** 2)

    return wd, params0, loss_fn


def _cfg(**kw):
    base = dict(
        aggregator="obcsaa", scheduler="greedy_batched", rounds=8,
        eval_every=4, learning_rate=0.3,
        obcsaa=OBCSAAConfig(chunk=64, measure=32, topk=8, biht_iters=4,
                            recon_alg="iht", recon_tau=0.25),
        const=AnalysisConstants(rho1=200.0, G=1.0))
    base.update(kw)
    return FLConfig(**base)


def test_run_sweep_emits_dense_budget_and_bound_dominates(task):
    """run_sweep returns per-arm-round ErrorBudget leaves + rt_bound and,
    with the probe on, the measured ‖ĝ−ḡ‖² — with the predicted bound
    dominating the measurement at every round of every arm."""
    wd, params0, loss_fn = task
    out = run_sweep(_cfg(probe_agg_error=True), loss_fn, params0, wd,
                    np.full(U, 16.0), rounds=6,
                    noise_var=[1e-6, 1e-2])
    assert isinstance(out["budget"], ErrorBudget)
    for leaf in out["budget"]:
        assert leaf.shape == (2, 6)
    assert out["rt_bound"].shape == (2, 6)
    assert out["agg_err"].shape == (2, 6)
    assert np.all(np.isfinite(out["rt_bound"]))
    assert np.all(out["rt_bound"] >= out["agg_err"])
    # budget identity holds on the engine-emitted leaves too
    b = out["budget"]
    np.testing.assert_array_equal(
        b.quantization + b.dim_reduction + b.noise + b.reconstruction
        + b.sparsification + b.scheduling, out["rt_bound"])


def test_budget_only_emitted_for_obcsaa(task):
    """Eq. 19 models the 1-bit CS pipeline: non-obcsaa aggregators emit
    no budget (no rt_bound key from run_sweep, NaN in SchedLog) while
    the probe still measures their aggregation error."""
    wd, params0, loss_fn = task
    cfg = _cfg(aggregator="topk_aa", topk_dense=24, probe_agg_error=True)
    out = run_sweep(cfg, loss_fn, params0, wd, np.full(U, 16.0),
                    rounds=3, noise_var=[1e-6, 1e-2])
    assert "rt_bound" not in out and "budget" not in out
    assert out["agg_err"].shape == (2, 3)
    tr = FederatedTrainer(cfg, loss_fn, params0, wd, np.full(U, 16.0))
    tr.run(3)
    assert np.all(np.isnan(tr.sched_trajectory["rt_bound"]))
    assert np.all(np.isfinite(tr.sched_trajectory["agg_err"]))


def test_probe_off_is_measure_zero_on_training(task):
    """FLConfig.probe_agg_error only adds outputs: params, EF residual
    and the dense scheduling stats are bitwise-unchanged with the probe
    on vs off (the DESIGN.md §12 measure-zero contract), and off is the
    default — the PR-4 parity suite runs against that default."""
    wd, params0, loss_fn = task
    outs = {}
    for probe in (False, True):
        tr = FederatedTrainer(_cfg(probe_agg_error=probe,
                                   error_feedback=True),
                              loss_fn, params0, wd, np.full(U, 16.0))
        tr.run()
        outs[probe] = tr
    a, b = outs[False], outs[True]
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert np.array_equal(np.asarray(a._state.residual),
                          np.asarray(b._state.residual))
    traj_a, traj_b = a.sched_trajectory, b.sched_trajectory
    np.testing.assert_array_equal(traj_a["n_scheduled"],
                                  traj_b["n_scheduled"])
    np.testing.assert_array_equal(traj_a["b_t"], traj_b["b_t"])
    np.testing.assert_array_equal(traj_a["rt_bound"], traj_b["rt_bound"])
    assert np.all(np.isnan(traj_a["agg_err"]))
    assert np.all(np.isfinite(traj_b["agg_err"]))
    assert FLConfig().probe_agg_error is False


def test_probe_matches_host_computed_error(task):
    """The in-scan ‖ĝ−ḡ‖² equals a host-side recomputation: ĝ recovered
    from the SGD parameter step, ḡ from re-evaluating the stacked worker
    gradients at the pre-round params (host reference path, so β is
    observable per round)."""
    wd, params0, loss_fn = task
    cfg = _cfg(mode="host", probe_agg_error=True, rounds=4)
    kw = jnp.full((U,), 16.0)
    tr = FederatedTrainer(cfg, loss_fn, params0, wd, np.full(U, 16.0))
    from repro.core.sparsify import flatten_pytree
    for t in range(cfg.rounds):
        params_before = tr.params
        info = tr.run_round(t)
        flat_b, _ = flatten_pytree(params_before)
        flat_a, _ = flatten_pytree(tr.params)
        ghat = (np.asarray(flat_b) - np.asarray(flat_a)) \
            / cfg.learning_rate
        grads = stacked_grads(loss_fn, params_before, wd)
        ideal = np.asarray(perfect_aggregate(
            grads, kw, jnp.asarray(info["beta"])))
        expect = float(np.sum((ghat - ideal) ** 2))
        got = tr.sched_logs[t].agg_err
        assert got == pytest.approx(expect, rel=1e-3), t


def test_host_and_scan_log_identical_theory_stats(task):
    """rt_bound/agg_err in the dense SchedLog stream agree between the
    scan engine and the host reference loop (the §11 parity convention
    extended to the theory outputs)."""
    wd, params0, loss_fn = task
    logs = {}
    for mode in ("scan", "host"):
        tr = FederatedTrainer(_cfg(mode=mode, probe_agg_error=True),
                              loss_fn, params0, wd, np.full(U, 16.0))
        tr.run()
        logs[mode] = tr.sched_trajectory
    np.testing.assert_allclose(logs["scan"]["rt_bound"],
                               logs["host"]["rt_bound"], rtol=1e-6)
    np.testing.assert_allclose(logs["scan"]["agg_err"],
                               logs["host"]["agg_err"], rtol=1e-5)
