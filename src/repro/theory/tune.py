"""Bound-driven design-parameter tuning (paper eq. 24; DESIGN.md §12).

The companion joint-optimization line of work (arXiv:2104.03490,
arXiv:2310.10089) selects design parameters by evaluating the predicted
convergence bound, not by running training grids. ``tune_design`` does
that for this repo's knobs: it sweeps (κ_c, S_c, decode budget)
candidates over the closed-form objective R_t = 2L·B_t in ONE broadcast
evaluation (the candidate axis rides ``repro.theory.bounds``'s array
support — no Python loop, no retrace per candidate) and returns the
Pareto frontier over (R_t, uplink symbols, decode FLOPs).

What makes the sweep non-trivial: R_t alone is monotone — more
measurements and a larger κ always shrink eq. (19). The real tradeoff
enters through the RIP constant: sparser recovery from fewer measurements
degrades δ, and C(δ) in eq. (46) blows up as δ → √2 − 1. ``delta_model``
carries the standard Gaussian-RIP scaling δ ∝ √(κ·ln(e·D_c/κ)/S_c),
one-point-calibrated against the Monte-Carlo estimator
``core.measurement.rip_constant_estimate`` at a reference design
(``calibrate_delta``), so for a fixed symbol budget there is an interior
optimal κ_c: too small pays sparsification error, too large pays C(δ)².
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.theory.bounds import AnalysisConstants, error_budget


def delta_model(kappa, s_c, d_chunk, *, calib: float = 1.0):
    """Gaussian-RIP scaling δ(κ, S_c) = calib·√(κ·ln(e·D_c/κ)/S_c).

    The standard sufficient condition for RIP-δ of an S_c×D_c i.i.d.
    Gaussian ensemble at sparsity κ is S_c ≳ δ⁻²·κ·ln(e·D_c/κ); solving
    for δ gives the model. ``calib`` absorbs the unknown universal
    constant — fit it with ``calibrate_delta`` (DESIGN.md §12)."""
    kappa = jnp.asarray(kappa, jnp.float32)
    s_c = jnp.asarray(s_c, jnp.float32)
    d_chunk = jnp.asarray(d_chunk, jnp.float32)
    return calib * jnp.sqrt(kappa * jnp.log(math.e * d_chunk / kappa) / s_c)


def calibrate_delta(d_chunk: int, *, kappa_ref: int, s_ref: int,
                    n_trials: int = 32, seed: int = 1) -> float:
    """One-point calibration of ``delta_model``: Monte-Carlo δ at a
    reference (κ_ref, S_ref) via ``rip_constant_estimate`` (eq. 41),
    divided by the model's uncalibrated value there."""
    # deferred import: repro.core re-exports repro.theory names, so a
    # module-scope core import would be circular (DESIGN.md §12)
    from repro.core.measurement import make_phi, rip_constant_estimate
    phi = make_phi(0, s_ref, d_chunk)
    delta_ref = float(rip_constant_estimate(phi, kappa_ref,
                                            n_trials=n_trials, seed=seed))
    raw = float(delta_model(kappa_ref, s_ref, d_chunk, calib=1.0))
    return delta_ref / raw


def pareto_mask(objectives: np.ndarray) -> np.ndarray:
    """Boolean non-dominated mask for an (N, M) minimize-all objective
    matrix. A candidate is on the frontier iff no other candidate is ≤ in
    every objective and < in at least one; non-finite rows never
    qualify."""
    obj = np.asarray(objectives, np.float64)
    finite = np.all(np.isfinite(obj), axis=1)
    # [j, i]: candidate j weakly/strictly better than candidate i
    le = np.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = np.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    dominated = np.any(le & lt, axis=0)
    return finite & ~dominated


def tune_design(c: AnalysisConstants, *, D: int, d_chunk: int,
                kappas: Sequence[int], measures: Sequence[int],
                decode_iters: Sequence[int] = (10,),
                k_weights, noise_var, b_t, beta=None,
                calib: Optional[float] = None,
                max_symbols: Optional[float] = None) -> Dict:
    """Sweep the (κ_c, S_c, decode-iteration) design grid over the
    closed-form R_t (eq. 24) in one broadcast evaluation (DESIGN.md §12).

    The channel/scheduling context is a nominal operating point: β
    (default: everyone scheduled), per-worker ``k_weights``, the power
    scale ``b_t`` and receiver ``noise_var`` — the quantities the engine
    logs per round, so a tuned design can be cross-checked against a
    measured trajectory (benchmarks/theory_bench.py).

    Returns a dict of (N,) arrays over the flattened grid: the candidate
    axes (``kappa``/``measure``/``iters``), the modeled ``delta``, the
    predicted ``rt`` (+inf where δ breaks eq. 46), per-round uplink
    ``symbols`` (S_c + 1 magnitude symbol per chunk, DESIGN.md §4) and
    decode ``flops``, the ``pareto`` frontier mask over
    (rt, symbols, flops), and ``best`` — the argmin-R_t index, restricted
    to ``symbols ≤ max_symbols`` when a budget is given. Raises
    ``ValueError`` when no candidate is both RIP-feasible and within
    budget — silently handing back a grid corner would let an infeasible
    budget masquerade as a tuned design."""
    k_weights = jnp.asarray(k_weights, jnp.float32)
    beta = (jnp.ones_like(k_weights) if beta is None
            else jnp.asarray(beta, jnp.float32))
    if calib is None:
        calib = calibrate_delta(d_chunk, kappa_ref=int(kappas[0]),
                                s_ref=int(measures[-1]))
    kg, sg, ig = np.meshgrid(np.asarray(kappas, np.float32),
                             np.asarray(measures, np.float32),
                             np.asarray(decode_iters, np.float32),
                             indexing="ij")
    kappa = jnp.asarray(kg.ravel())
    s_c = jnp.asarray(sg.ravel())
    iters = jnp.asarray(ig.ravel())

    n_chunks = -(-D // d_chunk)
    # RIP is a per-chunk property of the block-diagonal Φ (DESIGN.md §4);
    # the error terms see the effective whole-vector totals n·κ_c / n·S_c
    delta = delta_model(kappa, s_c, d_chunk, calib=calib)
    budget = error_budget(c, D=D, S=n_chunks * s_c,
                          kappa=jnp.minimum(n_chunks * kappa, float(D)),
                          beta=beta, k_weights=k_weights, b_t=b_t,
                          noise_var=noise_var, delta=delta)
    rt = np.asarray(budget.rt(), np.float64)
    symbols = n_chunks * (np.asarray(s_c, np.float64) + 1.0)
    # per decode iteration: one projection + one back-projection GEMM
    flops = (np.asarray(iters, np.float64)
             * 4.0 * np.asarray(s_c, np.float64) * d_chunk * n_chunks)
    mask = pareto_mask(np.stack([rt, symbols, flops], axis=1))
    feasible = np.isfinite(rt)
    if max_symbols is not None:
        feasible &= symbols <= float(max_symbols)
    if not feasible.any():
        raise ValueError(
            "tune_design: no candidate is RIP-feasible"
            + (f" within max_symbols={max_symbols}"
               if max_symbols is not None else "")
            + " — widen the grid or raise the budget")
    best = int(np.argmin(np.where(feasible, rt, np.inf)))
    return {"kappa": np.asarray(kappa, np.int64),
            "measure": np.asarray(s_c, np.int64),
            "iters": np.asarray(iters, np.int64),
            "delta": np.asarray(delta),
            "rt": rt, "symbols": symbols, "flops": flops,
            "pareto": mask, "best": best, "calib": float(calib),
            "budget": budget}
