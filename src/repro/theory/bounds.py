"""Vectorized Theorem-1 convergence engine (paper §III; DESIGN.md §12).

The paper's central analytical contribution is a closed-form expression
for the expected convergence rate of FL over the air, decomposing the
per-round aggregation error into the five sources named in the abstract:
sparsification, dimension reduction, quantization, signal reconstruction
and noise.

- Lemma 1 (eq. 19) bounds the total aggregation error
  E‖e_t‖² ≤ C²(1 + (1+δ)(D−κ)/(SD)·G² + σ²/(ΣK_iβ_ib_t)²)
           + Σ_iβ_i(1+δ)(D−κ)/D·G².
- Theorem 1 (eq. 20-21) turns the per-round bound B_t into a convergence
  rate with α = 1/L; the descent recursion Δ_{t+1} ≤ ρ₂Δ_t + B_t drives
  E[F(w_t)−F(w*)] toward the error floor B/(1−ρ₂).
- Eq. (24) regroups 2L·B_t into the R_t objective the P2 schedulers of
  ``repro.sched`` minimize (DESIGN.md §10).

``error_budget`` materializes the bound as an ``ErrorBudget`` pytree — one
named leaf per error source — so the engine can emit it as a dense scan
output next to the scheduling stats (DESIGN.md §11/§12) and a sweep's
whole seeds×SNR grid gets per-round predicted bounds from one compiled
program. Every function reduces over the LAST axis only and accepts
array-valued D/S/κ/δ, so the same code evaluates one round, a scanned
trajectory, a vmapped arms grid, or the tuner's candidate grid
(``repro.theory.tune``).

All quantities keep eq. (19)'s scale (squared-error units == R_t units);
divide by 2L for B_t. The fields sum — bitwise, in field order — to
``lemma1_error_bound`` because that function IS the sum.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

# Candès RIP condition: eq. (46)'s C(δ) is finite for δ < √2 − 1.
DELTA_MAX = math.sqrt(2.0) - 1.0


@dataclass(frozen=True)
class AnalysisConstants:
    """Paper's analysis constants (Assumptions 1-4 + RIP)."""
    L: float = 10.0          # Lipschitz smoothness
    rho1: float = 1.0        # sample-gradient bound, eq. (17)
    rho2: float = 0.5        # sample-gradient slope, 0 <= rho2 < 1
    G: float = 10.0          # local gradient bound, eq. (18)
    delta: float = 0.2       # RIP constant (< sqrt(2)-1)

    @property
    def C(self) -> float:
        # deferred import: repro.core re-exports this package's names, so
        # a module-scope core import would be circular (DESIGN.md §12)
        from repro.core.measurement import reconstruction_constant
        return reconstruction_constant(self.delta)


def reconstruction_constant_traced(delta):
    """Array-valued eq. (46): C(δ) = 2ϖ/(1−ϱ), +inf where δ ≥ √2 − 1.

    The scalar ``core.measurement.reconstruction_constant`` raises on an
    invalid δ; the tuner sweeps δ(κ, S) grids through jit, so infeasible
    candidates must yield +inf instead (their R_t then loses every
    comparison, DESIGN.md §12)."""
    delta = jnp.asarray(delta, jnp.float32)
    d = jnp.clip(delta, 0.0, 0.99)          # keep the sqrts defined
    varpi = 2.0 * jnp.sqrt(1.0 + d) / jnp.sqrt(1.0 - d)
    varrho = jnp.sqrt(2.0) * d / (1.0 - d)
    c = 2.0 * varpi / jnp.maximum(1.0 - varrho, 1e-9)
    return jnp.where(delta < DELTA_MAX, c, jnp.inf)


class ErrorBudget(NamedTuple):
    """Per-round error budget: eq. (19)/(21)/(24) split into the paper
    abstract's five aggregation-error sources plus the scheduling
    exclusion penalty (DESIGN.md §12). All leaves broadcast together;
    in-scan each is a scalar per (arm, round).

    The five error fields sum (in field order) to the Lemma-1 bound:
    quantization, dim_reduction and noise are the pre-C² terms inside
    eq. (19)'s parenthesis; reconstruction is the (C²−1)-excess the
    decoding constant C(δ) multiplies onto them; sparsification is the
    top-κ term outside C². ``scheduling`` is eq. (21)'s (1−β) penalty on
    the R_t = 2L·B_t scale — zero under full participation, NOT part of
    eq. (19)."""
    quantization: jnp.ndarray      # 1 — the unit sign-quantization floor
    dim_reduction: jnp.ndarray     # (1+δ)(D−κ)/(SD)·G²
    noise: jnp.ndarray             # σ²/(ΣK_iβ_ib_t)²
    reconstruction: jnp.ndarray    # (C²(δ)−1)·(the three terms above)
    sparsification: jnp.ndarray    # Σβ_i(1+δ)(D−κ)/D·G²
    scheduling: jnp.ndarray        # ΣK_iρ₁(1−β_i)/ΣK_i  (eq. 21 × 2L)

    def total_error(self) -> jnp.ndarray:
        """Eq. (19): the Lemma-1 aggregation-error bound (field-order
        sum; the bitwise contract of ``lemma1_error_bound``)."""
        return (self.quantization + self.dim_reduction + self.noise
                + self.reconstruction + self.sparsification)

    def rt(self) -> jnp.ndarray:
        """Eq. (24): R_t = 2L·B_t — the P2 objective (DESIGN.md §10)."""
        return self.scheduling + self.total_error()

    def bt(self, L: float) -> jnp.ndarray:
        """Eq. (21): B_t, the per-round term of Theorem 1."""
        return self.rt() / (2.0 * L)


def error_budget(c: AnalysisConstants, *, D, S, kappa, beta, k_weights,
                 b_t, noise_var, delta=None) -> ErrorBudget:
    """Eq. (19)/(21) as an ``ErrorBudget`` pytree (DESIGN.md §12).

    ``beta``/``k_weights`` are (..., U) and reduce over the last axis;
    every other argument broadcasts against the leading axes, so one call
    covers a scalar round, a (rounds,) trajectory, an (arms, rounds)
    grid, or the tuner's candidate axis. ``D``/``S``/``kappa`` may be
    arrays; ``delta=None`` uses the static ``c.delta``/``c.C`` (the
    engine path), an array δ routes through the traced C(δ)."""
    beta = jnp.asarray(beta, jnp.float32)
    k_weights = jnp.asarray(k_weights, jnp.float32)
    D = jnp.asarray(D, jnp.float32)
    S = jnp.asarray(S, jnp.float32)
    kappa = jnp.asarray(kappa, jnp.float32)
    if delta is None:
        delta = jnp.float32(c.delta)
        C2 = jnp.float32(c.C ** 2)
    else:
        delta = jnp.asarray(delta, jnp.float32)
        C2 = reconstruction_constant_traced(delta) ** 2
    G2 = jnp.float32(c.G ** 2)

    s_beta = jnp.sum(beta, axis=-1)
    s_k = jnp.sum(k_weights * beta, axis=-1)
    K = jnp.sum(k_weights, axis=-1)
    denom = s_k * jnp.asarray(b_t, jnp.float32)

    quant = jnp.ones_like(C2 * denom)       # broadcast to the output shape
    dim_red = (1.0 + delta) * (D - kappa) / (S * D) * G2 * quant
    noise = (jnp.asarray(noise_var, jnp.float32)
             / jnp.maximum(denom ** 2, 1e-30))
    recon = (C2 - 1.0) * (quant + dim_red + noise)
    sparse = s_beta * (1.0 + delta) * (D - kappa) / D * G2
    sched = jnp.sum(k_weights * c.rho1 * (1.0 - beta), axis=-1) / K
    shape = jnp.broadcast_shapes(quant.shape, dim_red.shape, noise.shape,
                                 recon.shape, sparse.shape, sched.shape)
    b = lambda x: jnp.broadcast_to(x, shape)
    return ErrorBudget(quantization=b(quant), dim_reduction=b(dim_red),
                       noise=b(noise), reconstruction=b(recon),
                       sparsification=b(sparse), scheduling=b(sched))


def lemma1_error_bound(c: AnalysisConstants, *, D, S, kappa, beta,
                       k_weights, b_t, noise_var, delta=None):
    """Eq. (19) — BY DEFINITION the field-order sum of the
    ``ErrorBudget`` error terms, so the decomposition is bitwise-exact
    (tests/test_theory.py)."""
    return error_budget(c, D=D, S=S, kappa=kappa, beta=beta,
                        k_weights=k_weights, b_t=b_t,
                        noise_var=noise_var, delta=delta).total_error()


def bt_term(c: AnalysisConstants, *, D, S, kappa, beta, k_weights, b_t,
            noise_var, delta=None):
    """Eq. (21): B_t."""
    return error_budget(c, D=D, S=S, kappa=kappa, beta=beta,
                        k_weights=k_weights, b_t=b_t,
                        noise_var=noise_var, delta=delta).bt(c.L)


def rt_objective(c: AnalysisConstants, *, D, S, kappa, beta, k_weights,
                 b_t, noise_var, delta=None):
    """Eq. (24): R_t = 2L·B_t — the joint-optimization objective."""
    return error_budget(c, D=D, S=S, kappa=kappa, beta=beta,
                        k_weights=k_weights, b_t=b_t,
                        noise_var=noise_var, delta=delta).rt()


def theorem1_rate(c: AnalysisConstants, *, T: int, f0_minus_fstar: float,
                  bt_sum: float):
    """Eq. (20): bound on (1/T) Σ ‖∇F‖²."""
    lead = 2.0 * c.L / (T * (1.0 - c.rho2))
    return lead * f0_minus_fstar + lead * bt_sum


def theorem1_trajectory(c: AnalysisConstants, f0_minus_fstar,
                        bt_series: jnp.ndarray) -> jnp.ndarray:
    """The full expected-convergence-rate trajectory of Theorem 1: unroll
    the descent recursion Δ_{t+1} = ρ₂·Δ_t + B_t from
    Δ_0 = F(w_0) − F(w*), giving the per-round bound on
    E[F(w_t) − F(w*)] (DESIGN.md §12).

    ``bt_series`` is (..., T) with time on the LAST axis (the engine's
    (arms, rounds) layout); leading axes are carried elementwise, so a
    whole sweep's trajectories unroll in one scan. With constant B the
    trajectory converges geometrically to ``error_floor_asymptote``."""
    bt_series = jnp.asarray(bt_series, jnp.float32)
    d0 = jnp.broadcast_to(jnp.asarray(f0_minus_fstar, jnp.float32),
                          bt_series.shape[:-1])
    rho2 = jnp.float32(c.rho2)

    def step(delta, b):
        nd = rho2 * delta + b
        return nd, nd

    _, traj = lax.scan(step, d0, jnp.moveaxis(bt_series, -1, 0))
    return jnp.moveaxis(traj, 0, -1)


def error_floor_asymptote(c: AnalysisConstants, bt):
    """Steady state of the Theorem-1 recursion: lim_t Δ_t = B/(1−ρ₂) for
    constant B_t = B — the scheme's irreducible error floor."""
    return jnp.asarray(bt, jnp.float32) / (1.0 - c.rho2)
