"""repro.theory — closed-form convergence analytics (paper §III;
DESIGN.md §12).

The paper's Lemma 1 / Theorem 1 analysis as an executable subsystem:
``bounds`` is the vectorized, vmap/scan-safe Theorem-1 engine (the
``ErrorBudget`` pytree splits eq. 19/21 into the abstract's five
aggregation-error sources, and the engine emits it per round as dense
scan outputs); ``tune`` sweeps design-parameter grids over the
closed-form R_t objective (eq. 24) with an RIP-calibrated δ(κ, S_c)
model and returns the Pareto frontier.

Layering: sits beside ``repro.decode``/``repro.sched`` — imports only
the ``repro.core.measurement`` leaf (for C(δ) and RIP calibration);
``repro.sched`` consumes ``AnalysisConstants`` from here, and
``repro.engine`` threads the budget through its scan (DESIGN.md §12).
"""
from repro.theory.bounds import (AnalysisConstants, DELTA_MAX, ErrorBudget,
                                 bt_term, error_budget,
                                 error_floor_asymptote, lemma1_error_bound,
                                 reconstruction_constant_traced,
                                 rt_objective, theorem1_rate,
                                 theorem1_trajectory)
from repro.theory.tune import (calibrate_delta, delta_model, pareto_mask,
                               tune_design)

__all__ = [
    "AnalysisConstants", "DELTA_MAX", "ErrorBudget", "bt_term",
    "calibrate_delta", "delta_model", "error_budget",
    "error_floor_asymptote", "lemma1_error_bound", "pareto_mask",
    "reconstruction_constant_traced", "rt_objective", "theorem1_rate",
    "theorem1_trajectory", "tune_design",
]
