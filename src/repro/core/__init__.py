"""The paper's primary contribution: OBCSAA + convergence analysis + P2 solvers.

The convergence analysis itself lives in ``repro.theory`` (DESIGN.md §12);
the names below stay re-exported for compatibility."""
from repro.theory.bounds import (AnalysisConstants, bt_term,
                                 lemma1_error_bound, rt_objective,
                                 theorem1_rate)
from repro.core.obcsaa import (OBCSAAConfig, comm_stats, compress_chunks,
                               reconstruct_chunks, shardmap_aggregate,
                               shardmap_compress, shardmap_reconstruct,
                               simulate_round)
from repro.sched.reference import (Problem, admm_solve, enumerate_solve,
                                   greedy_solve, optimal_bt)

__all__ = [
    "AnalysisConstants", "OBCSAAConfig", "Problem", "admm_solve", "bt_term",
    "comm_stats", "compress_chunks", "enumerate_solve", "greedy_solve",
    "lemma1_error_bound", "optimal_bt", "reconstruct_chunks", "rt_objective",
    "shardmap_aggregate", "shardmap_compress", "shardmap_reconstruct",
    "simulate_round", "theorem1_rate",
]
