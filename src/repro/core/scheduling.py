"""Joint worker-scheduling + power-scaling optimization (paper §IV).

P2:  min_{b_t, β_t} R_t   s.t.  β_i² K_i² b_t² / h_i² ≤ P_i^Max, β ∈ {0,1}^U.

Two solvers, as in the paper:
- Algorithm 1 (``enumerate_solve``): exact — enumerate 2^U − 1 schedules; for
  fixed β the optimal b_t is closed-form (R_t is strictly decreasing in b_t,
  so b_t* sits on the tightest power boundary).
- Algorithm 2 (``admm_solve``): O(U) ADMM on the P3 reformulation with
  auxiliaries r_i = β_i q_i, q_i = b_t and multipliers (ν, ξ, ς).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core.error_floor import AnalysisConstants


@dataclass(frozen=True)
class Problem:
    """One round's P2 instance."""
    h: np.ndarray            # (U,) channel magnitudes
    k_weights: np.ndarray    # (U,) K_i
    p_max: float             # P^Max (same for all workers, as in §V)
    noise_var: float         # σ²
    D: int
    S: int
    kappa: int
    const: AnalysisConstants

    @property
    def U(self) -> int:
        return len(self.h)


def _rt(prob: Problem, beta: np.ndarray, b_t: float) -> float:
    c = prob.const
    K = prob.k_weights.sum()
    denom = float((prob.k_weights * beta).sum()) * b_t
    if denom <= 0:
        return np.inf
    C2 = c.C ** 2
    r = (prob.k_weights * c.rho1 * (1.0 - beta)).sum() / K
    r += C2 * (1.0 + (1.0 + c.delta) * (prob.D - prob.kappa)
               / (prob.S * prob.D) * c.G ** 2
               + prob.noise_var / denom ** 2)
    r += beta.sum() * (1.0 + c.delta) * (prob.D - prob.kappa) / prob.D \
        * c.G ** 2
    return float(r)


def optimal_bt(prob: Problem, beta: np.ndarray) -> float:
    """R_t strictly decreases in b_t ⇒ b_t* = min_i scheduled h_i √P / K_i."""
    sel = beta > 0
    if not sel.any():
        return 0.0
    caps = prob.h[sel] * np.sqrt(prob.p_max) / prob.k_weights[sel]
    return float(caps.min())


def enumerate_solve(prob: Problem) -> Tuple[np.ndarray, float, float]:
    """Algorithm 1. Returns (β*, b_t*, R_t*). O(2^U) — small U only."""
    U = prob.U
    best = (None, 0.0, np.inf)
    for bits in itertools.product((0, 1), repeat=U):
        beta = np.asarray(bits, np.float64)
        if beta.sum() == 0:
            continue
        b = optimal_bt(prob, beta)
        r = _rt(prob, beta, b)
        if r < best[2]:
            best = (beta, b, r)
    return best


def _step1_rb(prob: Problem, q, beta, nu, xi, zeta, b_prev, c_step,
              inner_iters=50):
    """Minimize L wrt (r, b): projected gradient on r (smooth convex) with
    per-coordinate curvature steps, closed form for b."""
    c2s2 = prob.const.C ** 2 * prob.noise_var
    K = prob.k_weights
    r = np.maximum(beta * q, 1e-8)
    # per-coordinate Lipschitz of the quadratic parts
    lip = 2.0 * nu * K ** 2 / prob.h ** 2 + c_step + 1e-6
    for _ in range(inner_iters):
        denom = max(float((K * r).sum()), 1e-9)
        gQ1 = -2.0 * c2s2 / denom ** 3 * K
        gpen = nu * 2.0 * K ** 2 * r / prob.h ** 2
        glin = xi + c_step * (r - beta * q)
        g = gQ1 + gpen + glin
        r = np.maximum(r - g / lip, 1e-9)
    b = float(np.mean(q) + np.mean(zeta) / c_step)
    b = max(b, 1e-9)
    return r, b


def _step2_qbeta(prob: Problem, r, b, nu, xi, zeta, c_step):
    """Per-worker closed forms for q under β=0 / β=1, pick the smaller
    objective (eq. 34-36)."""
    c = prob.const
    K = prob.k_weights
    Ksum = K.sum()
    # beta = 0: q = b - zeta/c
    q0 = np.maximum(b - zeta / c_step, 1e-9)
    obj0 = (K * c.rho1 / Ksum
            + xi * r + 0.5 * c_step * r ** 2
            + zeta * (q0 - b) + 0.5 * c_step * (q0 - b) ** 2)
    # beta = 1: q = (xi - zeta + c r + c b) / (2c)
    q1 = np.maximum((xi - zeta + c_step * (r + b)) / (2.0 * c_step), 1e-9)
    obj1 = ((1.0 + c.delta) * (prob.D - prob.kappa) / prob.D * c.G ** 2
            + xi * (r - q1) + 0.5 * c_step * (r - q1) ** 2
            + zeta * (q1 - b) + 0.5 * c_step * (q1 - b) ** 2)
    beta = (obj1 < obj0).astype(np.float64)
    q = np.where(beta > 0, q1, q0)
    return q, beta


def admm_solve(prob: Problem, *, c_step: float = 1.0, max_iters: int = 200,
               abs_tol: float = 1e-4,
               rel_tol: float = 1e-5) -> Tuple[np.ndarray, float, float]:
    """Algorithm 2. Returns (β*, b_t*, R_t*). O(U) per iteration."""
    U = prob.U
    beta = np.ones(U)
    b = max(optimal_bt(prob, beta), 1e-6)   # feasible warm start
    q = np.full(U, b)
    nu = np.zeros(U)
    xi = np.zeros(U)
    zeta = np.zeros(U)
    for it in range(max_iters):
        r, b_new = _step1_rb(prob, q, beta, nu, xi, zeta, b, c_step)
        q, beta = _step2_qbeta(prob, r, b_new, nu, xi, zeta, c_step)
        # Step 3: multiplier updates (37)-(39); ν projected to >= 0
        nu = np.maximum(
            nu + c_step * ((prob.k_weights * r / prob.h) ** 2 - prob.p_max),
            0.0)
        xi = xi + c_step * (r - beta * q)
        zeta = zeta + c_step * (q - b_new)
        prim = float(np.abs(q - b_new).sum())
        drift = abs(b_new - b)
        b = b_new
        if prim < abs_tol and drift < rel_tol and it > 5:
            break
    # project: final β from ADMM, b_t from the exact power boundary
    if beta.sum() == 0:
        beta[np.argmax(prob.h * np.sqrt(prob.p_max) / prob.k_weights)] = 1.0
    # one O(U²) flip-polish pass (engineering refinement over the paper's
    # raw ADMM output; keeps the solver polynomial, documented in DESIGN.md)
    best_r = _rt(prob, beta, optimal_bt(prob, beta))
    improved = True
    sweeps = 0
    while improved and sweeps < 3:
        improved = False
        sweeps += 1
        for i in range(U):
            cand = beta.copy()
            cand[i] = 1.0 - cand[i]
            if cand.sum() == 0:
                continue
            r_c = _rt(prob, cand, optimal_bt(prob, cand))
            if r_c < best_r - 1e-12:
                beta, best_r = cand, r_c
                improved = True
    b_final = optimal_bt(prob, beta)
    return beta, b_final, _rt(prob, beta, b_final)


def greedy_solve(prob: Problem) -> Tuple[np.ndarray, float, float]:
    """Beyond-paper baseline: sort workers by channel quality cap
    h_i √P/K_i (descending); evaluate the U prefix schedules; pick best.
    O(U log U) and, because R_t depends on β only through Σβ, ΣK_iβ and the
    min-cap, the optimum is always a prefix of this ordering when K_i are
    equal — making it exact for the paper's §V setup."""
    caps = prob.h * np.sqrt(prob.p_max) / prob.k_weights
    order = np.argsort(-caps)
    best = (None, 0.0, np.inf)
    beta = np.zeros(prob.U)
    for i in order:
        beta[i] = 1.0
        b = optimal_bt(prob, beta)
        r = _rt(prob, beta, b)
        if r < best[2]:
            best = (beta.copy(), b, r)
    return best
