"""DEPRECATED — the P2 solvers moved to ``repro.sched`` (DESIGN.md §10).

This shim keeps old imports working with a warning: the symbols below are
the NumPy reference implementations, re-exported from
``repro.sched.reference`` (kept there as the parity oracle for the batched
device solvers). New code should call ``repro.sched.schedule`` (registry
dispatch) or import from ``repro.sched`` directly.
"""
from __future__ import annotations

import warnings

from repro.sched.reference import (Problem, _rt, admm_solve,  # noqa: F401
                                   enumerate_solve, greedy_solve,
                                   optimal_bt)

warnings.warn(
    "repro.core.scheduling has moved to repro.sched; this compat shim "
    "will be removed in a future PR (DESIGN.md §10).",
    DeprecationWarning, stacklevel=2)
