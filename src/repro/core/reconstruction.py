"""DEPRECATED — the 1-bit CS decoders moved to ``repro.decode`` (DESIGN.md
§9). This shim keeps old imports working with a warning; new code should
call ``repro.decode.decode`` (registry dispatch) or import the decoder
functions from ``repro.decode`` directly.
"""
from __future__ import annotations

import warnings

from repro.decode.iht import (biht_sign, hard_threshold,  # noqa: F401
                              iht, niht)

warnings.warn(
    "repro.core.reconstruction has moved to repro.decode; this compat shim "
    "will be removed in a future PR (DESIGN.md §9).",
    DeprecationWarning, stacklevel=2)


def reconstruct(y, phi, k, *, algorithm: str = "iht", iters: int = 10,
                tau: float = 1.0, ht_fn=None):
    """Deprecated alias for ``repro.decode.decode``; prefer the registry."""
    if algorithm == "iht":
        return iht(y, phi, k, iters, tau, ht_fn=ht_fn)
    if algorithm == "biht":
        return biht_sign(y, phi, k, iters, tau, ht_fn=ht_fn)
    raise ValueError(f"unknown reconstruction algorithm {algorithm!r}")
