"""1-bit CS reconstruction at the PS (paper §II-B.5, eq. 43).

The PS solves  min ||x||_1  s.t. ||ŷ − Φx||² ≤ ε  (eq. 43). We implement the
iterative-hard-thresholding family the paper selects (BIHT, Jacques et al.):

- ``iht``: x ← η_κ(x + τ Φᵀ(ŷ − Φx)) on the REAL post-processed aggregate ŷ
  (the paper's analysis, eq. 42-44, treats the 1-bit error as bounded noise on
  real measurements — this is the decoder used in the FL loop).
- ``biht_sign``: the classic single-worker BIHT with sign-consistency
  updates x ← η_κ(x + (τ/S) Φᵀ(y_sign − sign(Φx))), unit-normalized.

Magnitude note: sign measurements are scale-invariant, so the decoder
recovers direction; the aggregator transmits one extra analog scalar per
worker (the sparsified-gradient norm) to restore scale — standard "norm
estimation" in the 1-bit CS literature, recorded in DESIGN.md.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantize import sign_pm1


def hard_threshold(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the k largest-|.| entries along the last axis."""
    absx = jnp.abs(x)
    kth = jax.lax.top_k(absx, k)[0][..., -1:]
    mask = absx >= kth
    over = jnp.cumsum(mask, axis=-1) <= k
    return x * (mask & over)


def iht(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 10,
        tau: float = 1.0, ht_fn=None) -> jnp.ndarray:
    """IHT on real measurements. y: (..., S); phi: (S, D). Returns (..., D).

    tau is scaled by 1/||Φ||² proxy = 1 (Φ has unit spectral norm in
    expectation under the 1/S normalization)."""
    ht = ht_fn or hard_threshold

    def step(x, _):
        resid = y - jnp.einsum("sd,...d->...s", phi, x)
        x = x + tau * jnp.einsum("sd,...s->...d", phi, resid)
        return ht(x, k), None

    x0 = jnp.zeros(y.shape[:-1] + (phi.shape[1],), y.dtype)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def biht_sign(y_sign: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 30,
              tau: float = 1.0, ht_fn=None) -> jnp.ndarray:
    """Classic BIHT (sign-consistency subgradient), unit-norm output."""
    S = phi.shape[0]
    ht = ht_fn or hard_threshold

    def step(x, _):
        resid = y_sign - sign_pm1(jnp.einsum("sd,...d->...s", phi, x))
        x = x + (tau / S) * jnp.einsum("sd,...s->...d", phi, resid)
        x = ht(x, k)
        return x, None

    x0 = jnp.einsum("sd,...s->...d", phi, y_sign) / S
    x0 = ht(x0, k)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)


def reconstruct(y: jnp.ndarray, phi: jnp.ndarray, k: int, *,
                algorithm: str = "iht", iters: int = 10,
                tau: float = 1.0, ht_fn=None) -> jnp.ndarray:
    if algorithm == "iht":
        return iht(y, phi, k, iters, tau, ht_fn=ht_fn)
    if algorithm == "biht":
        return biht_sign(y, phi, k, iters, tau, ht_fn=ht_fn)
    raise ValueError(f"unknown reconstruction algorithm {algorithm!r}")
