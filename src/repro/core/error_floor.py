"""Closed-form convergence analysis (paper §III).

Lemma 1 — total aggregation error bound (eq. 19):
  E||e_t||² ≤ C²(1 + (1+δ)(D−κ)/(SD) G² + σ²/(Σ K_i β_i b_t)²)
             + Σ_i β_i (1+δ)(D−κ)/D G²

Theorem 1 — expected convergence rate (eq. 20-21) with α = 1/L; B_t is the
per-round error-floor contribution; R_t = 2L·B_t is the objective of the
joint optimization (eq. 24).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.measurement import reconstruction_constant


@dataclass(frozen=True)
class AnalysisConstants:
    """Paper's analysis constants (Assumptions 1-4 + RIP)."""
    L: float = 10.0          # Lipschitz smoothness
    rho1: float = 1.0        # sample-gradient bound, eq. (17)
    rho2: float = 0.5        # sample-gradient slope, 0 <= rho2 < 1
    G: float = 10.0          # local gradient bound, eq. (18)
    delta: float = 0.2       # RIP constant (< sqrt(2)-1)

    @property
    def C(self) -> float:
        return reconstruction_constant(self.delta)


def lemma1_error_bound(c: AnalysisConstants, *, D: int, S: int, kappa: int,
                       beta, k_weights, b_t, noise_var):
    """Eq. (19)."""
    beta = jnp.asarray(beta, jnp.float32)
    k_weights = jnp.asarray(k_weights, jnp.float32)
    denom = jnp.sum(k_weights * beta) * b_t
    C2 = c.C ** 2
    recon = C2 * (1.0
                  + (1.0 + c.delta) * (D - kappa) / (S * D) * c.G ** 2
                  + noise_var / jnp.maximum(denom ** 2, 1e-30))
    sparse = jnp.sum(beta) * (1.0 + c.delta) * (D - kappa) / D * c.G ** 2
    return recon + sparse


def bt_term(c: AnalysisConstants, *, D: int, S: int, kappa: int, beta,
            k_weights, b_t, noise_var):
    """Eq. (21): B_t."""
    k_weights = jnp.asarray(k_weights, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    K = jnp.sum(k_weights)
    sched = jnp.sum(k_weights * c.rho1 * (1.0 - beta)) / (2.0 * c.L * K)
    err = lemma1_error_bound(c, D=D, S=S, kappa=kappa, beta=beta,
                             k_weights=k_weights, b_t=b_t,
                             noise_var=noise_var) / (2.0 * c.L)
    return sched + err


def rt_objective(c: AnalysisConstants, *, D: int, S: int, kappa: int, beta,
                 k_weights, b_t, noise_var):
    """Eq. (24): R_t = 2L·B_t — the joint-optimization objective."""
    return 2.0 * c.L * bt_term(c, D=D, S=S, kappa=kappa, beta=beta,
                               k_weights=k_weights, b_t=b_t,
                               noise_var=noise_var)


def theorem1_rate(c: AnalysisConstants, *, T: int, f0_minus_fstar: float,
                  bt_sum: float):
    """Eq. (20): bound on (1/T) Σ ||∇F||²."""
    lead = 2.0 * c.L / (T * (1.0 - c.rho2))
    return lead * f0_minus_fstar + lead * bt_sum
