"""Compatibility re-export — the convergence analysis moved to
``repro.theory`` (DESIGN.md §12), the single source of truth for
eq. 19/21/24. Import from ``repro.theory``; this module stays as a
deprecation-free alias for existing callers.
"""
from repro.theory.bounds import (AnalysisConstants, ErrorBudget, bt_term,
                                 error_budget, lemma1_error_bound,
                                 rt_objective, theorem1_rate,
                                 theorem1_trajectory)

__all__ = [
    "AnalysisConstants", "ErrorBudget", "bt_term", "error_budget",
    "lemma1_error_bound", "rt_objective", "theorem1_rate",
    "theorem1_trajectory",
]
