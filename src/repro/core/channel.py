"""Wireless MAC model (paper §II-B.4).

Block Rayleigh fading: h_{i,t} drawn per (worker, round) from N(0,1) as in
the paper's §V simulation setup; AWGN z_t ~ N(0, σ²I) added at the PS. The
superposition property of the MAC is the arithmetic sum — in the distributed
runtime this sum IS the psum over the worker mesh axes.

CSI is known at both ends (paper footnote 3); channels are near-zero
clamped so the channel-inversion power control (eq. 10) stays bounded, which
models the paper's implicit "scheduled workers have usable channels".
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

H_MIN = 1e-3  # clamp |h| to keep 1/h bounded (worker would be unscheduled)


def draw_channels(key, n_workers: int, clamp: bool = True) -> jnp.ndarray:
    """|h_{i,t}| for one round. Paper §V: h ~ N(0,1) (Rayleigh magnitude)."""
    h = jax.random.normal(key, (n_workers,))
    h = jnp.abs(h)
    if clamp:
        h = jnp.maximum(h, H_MIN)
    return h


def draw_noise(key, shape, noise_var: float) -> jnp.ndarray:
    """AWGN z_t ~ N(0, σ²I) added at the PS receiver (eq. 12)."""
    return jax.random.normal(key, shape) * jnp.sqrt(
        jnp.asarray(noise_var, jnp.float32))


def mac_aggregate(symbols: jnp.ndarray, h: jnp.ndarray, p: jnp.ndarray,
                  noise: jnp.ndarray) -> jnp.ndarray:
    """Centralized (simulation) form of eq. (8):
    y = Σ_i h_i p_i c_i + z,  symbols: (U, S)."""
    return jnp.einsum("u,us->s", h * p, symbols) + noise


def post_process(y: jnp.ndarray, k_weights: jnp.ndarray, beta: jnp.ndarray,
                 b_t: jnp.ndarray) -> jnp.ndarray:
    """Eq. (13): divide by Σ_i K_i β_i b_t."""
    denom = jnp.sum(k_weights * beta) * b_t
    return y / jnp.maximum(denom, 1e-12)
