"""Wireless MAC model (paper §II-B.4).

Block Rayleigh fading: h_{i,t} = |g_{i,t}| with g ~ CN(0, 1) per (worker,
round) — the paper's §V setup; AWGN z_t ~ N(0, σ²I) added at the PS. The
superposition property of the MAC is the arithmetic sum — in the distributed
runtime this sum IS the psum over the worker mesh axes.

This module is the single owner of the fade draw (``draw_fades``): the FL
engine (DESIGN.md §11) and the fleet scenario generator
(``sched/scenario.py``) both step the same first-order Gauss-Markov
recursion g_t = ρ g_{t−1} + √(1−ρ²) w_t, w ~ CN(0, 1), whose stationary
marginal is CN(0, 1) — Rayleigh magnitudes with lag-ℓ autocorrelation ρ^ℓ;
ρ = 0 recovers the paper's i.i.d. per-round redraw.

CSI is known at both ends (paper footnote 3); channels are near-zero
clamped so the channel-inversion power control (eq. 10) stays bounded, which
models the paper's implicit "scheduled workers have usable channels".
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

H_MIN = 1e-3  # clamp |h| to keep 1/h bounded (worker would be unscheduled)


def draw_cn(key, shape) -> jnp.ndarray:
    """One draw of w ~ CN(0, 1): unit-variance circularly-symmetric
    complex Gaussian (E|w|² = 1), the Rayleigh-magnitude fade innovation."""
    re, im = jax.random.split(key)
    return (jax.random.normal(re, shape)
            + 1j * jax.random.normal(im, shape)) / jnp.sqrt(2.0)


def gauss_markov_step(g, key, rho) -> jnp.ndarray:
    """g_t = ρ g_{t−1} + √(1−ρ²) w_t — stationary at CN(0, 1), so the
    magnitude marginal stays Rayleigh for every ρ ∈ [0, 1)."""
    rho = jnp.asarray(rho, jnp.float32)
    innov = jnp.sqrt(jnp.maximum(1.0 - rho ** 2, 0.0))
    return rho * g + innov * draw_cn(key, jnp.shape(g))


def draw_fades(key, shape=None, *, rho=0.0, prev=None,
               clamp: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One round of block-fading magnitudes (paper §II-B.4, §V).

    Returns ``(|h| float32, g complex64)``: the clamped channel magnitudes
    and the complex fade state to carry into the next round. ``prev=None``
    draws the stationary initial state g ~ CN(0, 1) (supply ``shape``);
    otherwise g steps the Gauss-Markov recursion from ``prev`` (ρ = 0 is
    the paper's i.i.d. block-fading redraw)."""
    if prev is None:
        g = draw_cn(key, shape)
    else:
        g = gauss_markov_step(prev, key, rho)
    g = g.astype(jnp.complex64)
    h = jnp.abs(g).astype(jnp.float32)
    if clamp:
        h = jnp.maximum(h, H_MIN)
    return h, g


def rayleigh_cdf(x) -> jnp.ndarray:
    """F(x) = 1 − exp(−x²) for |CN(0, 1)| — the KS-test reference for the
    fade marginal (tests/test_engine.py)."""
    x = jnp.asarray(x, jnp.float32)
    return 1.0 - jnp.exp(-x ** 2)


def draw_channels(key, n_workers: int, clamp: bool = True) -> jnp.ndarray:
    """|h_{i,t}| for one round (i.i.d. Rayleigh; ``draw_fades`` shorthand
    without the carried complex state)."""
    return draw_fades(key, (n_workers,), clamp=clamp)[0]


def draw_noise(key, shape, noise_var: float) -> jnp.ndarray:
    """AWGN z_t ~ N(0, σ²I) added at the PS receiver (eq. 12)."""
    return jax.random.normal(key, shape) * jnp.sqrt(
        jnp.asarray(noise_var, jnp.float32))


def mac_aggregate(symbols: jnp.ndarray, h: jnp.ndarray, p: jnp.ndarray,
                  noise: jnp.ndarray) -> jnp.ndarray:
    """Centralized (simulation) form of eq. (8):
    y = Σ_i h_i p_i c_i + z,  symbols: (U, S)."""
    return jnp.einsum("u,us->s", h * p, symbols) + noise


def post_process(y: jnp.ndarray, k_weights: jnp.ndarray, beta: jnp.ndarray,
                 b_t: jnp.ndarray) -> jnp.ndarray:
    """Eq. (13): divide by Σ_i K_i β_i b_t."""
    denom = jnp.sum(k_weights * beta) * b_t
    return y / jnp.maximum(denom, 1e-12)
