"""1-bit quantization (paper eq. 7): C(g) = sign(Φ sparse_κ(g)).

sign(0) is mapped to +1 so every transmitted symbol is ±1 — required for the
gradient-independent power constraint (eq. 11). The predicate lives in ONE
place — ``repro.kernels.sign`` — and is re-exported here along with the
32-per-uint32 packed codec (``pack_signs``/``unpack_signs``) that
``OBCSAAConfig(packed=True)`` transmits on the wire (DESIGN.md §13).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.sign import (PACK, pack_signs, sign_pm1,  # noqa: F401
                                unpack_signs)


def quantization_error_bound(S: int, D: int, kappa: int, G: float,
                             delta: float) -> float:
    """Paper eq. (42): E||e^q||² ≤ S + (1+δ)(D−κ)/D G²."""
    return S + (1.0 + delta) * (D - kappa) / D * G ** 2


def pack_bits(signs: jnp.ndarray) -> jnp.ndarray:
    """Pack ±1 float symbols to uint8 bitmaps (8x wire-size reduction for the
    digital-fallback path; the analog path transmits symbols directly)."""
    bits = (signs > 0).astype(jnp.uint8).reshape(-1, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
    return jnp.sum(bits * weights[None], axis=1, dtype=jnp.uint8)


def unpack_bits(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of ``pack_bits``: uint8 bitmaps back to ±1 symbols (eq. 7)."""
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None]) & 1
    return (bits.astype(jnp.float32) * 2.0 - 1.0).reshape(-1)[:n]
