"""Top-κ sparsification (paper eq. 6).

``sparse_κ(g)`` keeps the κ largest-magnitude entries of g and zeroes the
rest. The chunked variant applies top-κ_c per chunk of D_c entries — the
TPU-native block formulation (DESIGN.md §4) that keeps selection local to a
VMEM tile and composes with the block-diagonal measurement operator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_sparsify(g: jnp.ndarray, k: int):
    """Dense top-k over the last axis. Returns (sparse_g, mask).

    Exactly k entries survive per row: the mask is scattered from
    ``lax.top_k``'s indices, which break exact-magnitude ties by value
    order then lowest index (measure-zero for float gradients). The
    scatter replaces the old threshold + cumsum tie-break — XLA CPU fused
    that cumsum into an O(chunk²) reduce-window, ~40× slower than the
    top_k itself (DESIGN.md §11 perf note)."""
    absg = jnp.abs(g)
    _, idx = jax.lax.top_k(absg, k)
    mask = jnp.zeros(g.shape, bool)
    mask = jnp.put_along_axis(mask, idx, True, axis=-1, inplace=False)
    return g * mask, mask


def topk_sparsify_bisect(g: jnp.ndarray, k: int, iters: int = 40):
    """SPMD-friendly top-k: bisection on the magnitude threshold.

    ``jax.lax.top_k`` lowers to a sort that GSPMD cannot partition — at
    production scale it all-gathers the full (n_chunks, chunk) gradient
    array (180 GB/leaf for mixtral experts, §Perf iteration 6). Bisection
    uses only elementwise ops + row reductions, which shard perfectly.
    Exact for rows with distinct magnitudes (ties may admit > k entries —
    measure-zero for float gradients); same algorithm as the Pallas
    ``topk_select`` kernel."""
    a = jnp.abs(g.astype(jnp.float32))
    hi = jnp.max(a, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    mask = a >= hi
    cnt_hi = jnp.sum(mask.astype(jnp.int32), axis=-1, keepdims=True)
    mask = jnp.where(cnt_hi >= k, mask, a >= lo)
    return g * mask, mask


def topk_sparsify_chunked(g: jnp.ndarray, k_per_chunk: int, chunk: int):
    """g: (..., n_chunks*chunk) or (n_chunks, chunk). Per-chunk top-k."""
    shp = g.shape
    if g.ndim == 1:
        assert g.size % chunk == 0, (g.size, chunk)
        gc = g.reshape(-1, chunk)
    else:
        gc = g
    sg, mask = topk_sparsify(gc, k_per_chunk)
    return sg.reshape(shp), mask.reshape(shp)


def sparsification_error_bound(D: int, kappa: int, G: float,
                               delta: float) -> float:
    """Paper eq. (40): E||e^s||^2 <= (1+δ) (D-κ)/D G²."""
    return (1.0 + delta) * (D - kappa) / D * G ** 2


def pad_to_chunks(flat: jnp.ndarray, chunk: int):
    """Zero-pad a flat vector to a multiple of `chunk`; returns (padded, D)."""
    D = flat.shape[0]
    rem = (-D) % chunk
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), flat.dtype)])
    return flat, D


def flatten_pytree(tree):
    """Flatten a gradient pytree to one float32 vector + unflatten closure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(vec):
        out = []
        off = 0
        for shp, sz, dt in zip(shapes, sizes, dtypes):
            out.append(vec[off:off + sz].reshape(shp).astype(dt))
            off += sz
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten
