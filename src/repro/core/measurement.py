"""Measurement matrix Φ (paper §II-B.2).

The paper draws Φ ∈ R^{S×D} i.i.d. N(0, 1/S), shared between workers and PS
ahead of transmission. Here Φ is generated from a seeded PRNG so "sharing"
is a 32-bit seed, and the production variant is block-diagonal: one
Φ_c ∈ R^{S_c×D_c} applied to every chunk (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_phi(seed: int, s_dim: int, d_dim: int, dtype=jnp.float32):
    """Φ with entries N(0, 1/S) — paper's normalization (§V)."""
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, (s_dim, d_dim))
            / jnp.sqrt(jnp.asarray(s_dim, jnp.float32))).astype(dtype)


def rip_constant_estimate(phi: jnp.ndarray, sparsity: int, n_trials: int = 64,
                          seed: int = 1):
    """Monte-Carlo estimate of the RIP constant δ for κ-sparse vectors:
    max deviation of ||Φx||²/||x||² from 1 over random κ-sparse x (eq. 41)."""
    s_dim, d_dim = phi.shape
    key = jax.random.PRNGKey(seed)

    def one(k):
        k1, k2 = jax.random.split(k)
        idx = jax.random.choice(k1, d_dim, (sparsity,), replace=False)
        vals = jax.random.normal(k2, (sparsity,))
        x = jnp.zeros((d_dim,)).at[idx].set(vals)
        r = jnp.sum((phi @ x) ** 2) / jnp.sum(x ** 2)
        return jnp.abs(r - 1.0)

    devs = jax.vmap(one)(jax.random.split(key, n_trials))
    return jnp.max(devs)


def reconstruction_constant(delta: float) -> float:
    """Paper eq. (46): C = 2ϖ/(1−ϱ), ϖ = 2√(1+δ)/√(1−δ), ϱ = √2·δ/(1−δ).

    Valid for δ ≤ √2 − 1 (Candès RIP condition) — raises otherwise; the
    traced, array-valued sibling used by the theory layer's tuner grids
    returns +inf instead (``repro.theory.bounds.
    reconstruction_constant_traced``, DESIGN.md §12)."""
    import math
    varpi = 2.0 * math.sqrt(1.0 + delta) / math.sqrt(1.0 - delta)
    varrho = math.sqrt(2.0) * delta / (1.0 - delta)
    if varrho >= 1.0:
        raise ValueError(f"delta={delta} violates RIP reconstruction bound")
    return 2.0 * varpi / (1.0 - varrho)


def project_chunked(phi: jnp.ndarray, g_chunks: jnp.ndarray):
    """Block-diagonal Φ-projection, the linear half of C(g) (eq. 7):
    g_chunks (n, D_c) -> (n, S_c). See DESIGN.md §4."""
    return jnp.einsum("sd,nd->ns", phi, g_chunks)
