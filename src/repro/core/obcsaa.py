"""OBCSAA — One-Bit Compressive-Sensing Analog Aggregation (paper §II).

End-to-end aggregator:  per worker  C(g) = sign(Φ · sparse_κ(g))  (eq. 7),
power-controlled superposition over the MAC (eq. 8-12), post-processing
(eq. 13), 1-bit CS decode via the ``repro.decode`` registry (eq. 43,
selected by ``OBCSAAConfig.decoder``; DESIGN.md §9), model update (eq. 14).

Two execution modes share the same compression core:

- ``simulate_round``: the paper's §V simulation — U workers' gradients are
  stacked on one device, the MAC sum is an einsum, channels/noise drawn from
  a PRNG. Used by the FL runtime + paper-figure benchmarks.
- ``shardmap_compress``/``shardmap_reconstruct``: the production path — each
  data-parallel shard IS a worker; the MAC superposition IS the psum over the
  worker mesh axes (DESIGN.md §3). Reconstruction is sharded over chunks.

The measurement operator is block-diagonal (chunked) per DESIGN.md §4; for
the paper's D=50,890 MLP one chunk of D_c=D reproduces the paper exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel as chan
from repro.core.measurement import make_phi
from repro.core.quantize import PACK, pack_signs, sign_pm1, unpack_signs
from repro.core.sparsify import topk_sparsify, topk_sparsify_bisect
from repro.decode import DecodeConfig
from repro.decode import decode as cs_decode
from repro.dist import collectives as coll


@dataclass(frozen=True)
class OBCSAAConfig:
    chunk: int = 4096            # D_c
    measure: int = 1024          # S_c
    topk: int = 409              # κ_c
    # Decode-side sparsity: the superposed gradient has κ̄ > κ (paper §II-B.2,
    # distinct per-worker supports). 0 -> heuristic min(4κ, S/2).
    recon_topk: int = 0
    biht_iters: int = 30
    recon_alg: str = "biht"      # BIHT (paper §V); "iht" also available
    recon_tau: float = 1.0
    # Decoder registry selection (repro.decode, DESIGN.md §9). "" keeps the
    # legacy recon_alg choice; any registered name overrides it.
    decoder: str = ""
    # Warm-start decode: the FL loop seeds round t's decode with round t−1's
    # raw estimate (temporal gradient correlation; reset on schedule change).
    warm_start: bool = False
    noise_var: float = 1e-4      # σ² (mW)
    p_max: float = 10.0          # P^Max (mW)
    phi_seed: int = 42
    magnitude_tracking: bool = True
    # SPMD-friendly top-k (bisection threshold; §Perf iteration 6):
    # jax.lax.top_k's sort cannot be partitioned by GSPMD and all-gathers the
    # full chunk array at production scale. The distributed train step turns
    # this on; the single-host simulation keeps exact sort-based top-k.
    spmd_topk: bool = False
    # Threshold-bisection budget for the spmd path (selection resolution
    # max·2^-iters; 40 over-resolves f32 — the engine bench runs 20 with a
    # selection-parity check, DESIGN.md §11). Applies to compression,
    # error-feedback splits and the decoder's hard threshold.
    bisect_iters: int = 40
    use_kernels: bool = False    # Pallas kernels (interpret on CPU)
    # Packed 1-bit codec (DESIGN.md §13): compress emits uint32 words (32
    # signs each) instead of f32 ±1 symbols, and the shard-mapped MAC
    # accumulates them as exact int32 bit-counts before the power scale —
    # 32x less uplink signal traffic, bit-for-bit equal to the f32 path.
    # Requires measure % 32 == 0 and uniform K_i·b_t on the wire path.
    packed: bool = False
    # Fixed-step decode stability guard (DESIGN.md §13): "off" | "raise" |
    # "fallback" — checks τ against the restricted spectral estimate of Φ
    # before running the iht family (divergence would silently return NaN).
    decode_validate: str = "off"

    def __post_init__(self):
        if self.packed and self.measure % PACK:
            raise ValueError(
                f"OBCSAAConfig(packed=True) needs measure (S_c) to be a "
                f"multiple of {PACK}; got {self.measure} (DESIGN.md §13)")

    def phi(self, dtype=jnp.float32):
        return make_phi(self.phi_seed, self.measure, self.chunk, dtype)

    @property
    def decode_k(self) -> int:
        return self.recon_topk or min(4 * self.topk, self.measure // 2)

    def decode_cfg(self) -> DecodeConfig:
        """Map the aggregation knobs onto a registry DecodeConfig. The
        warm-start selection swaps ``iht`` for its warm-capable alias so
        carried state is actually consumed, and REJECTS decoders that
        would silently drop it (DESIGN.md §9)."""
        alg = self.decoder or self.recon_alg
        if self.warm_start:
            if alg == "iht":
                alg = "iht_warm"
            from repro.decode import get_decoder
            if not get_decoder(alg).warm:
                raise ValueError(
                    f"warm_start=True but decoder {alg!r} is not "
                    "warm-capable (state would be silently dropped); use "
                    "iht, iht_warm or iht_fused")
        return DecodeConfig(algorithm=alg, iters=self.biht_iters,
                            tau=self.recon_tau, use_kernels=self.use_kernels,
                            ht="bisect" if self.spmd_topk else "sort",
                            ht_iters=self.bisect_iters,
                            validate=self.decode_validate)


# --- compression core (per worker) ---------------------------------------------

def compress_chunks(cfg: OBCSAAConfig, flat: jnp.ndarray, phi=None,
                    presparsified: bool = False):
    """Per-worker compression C(g) = sign(Φ sparse_κ(g)) (eq. 6-7), chunked.

    flat: (D_pad,) with D_pad % chunk == 0, or pre-chunked (n, chunk).
    Returns (signs (n_chunks, S_c), mags (n_chunks,)) — with
    ``cfg.packed``, signs is instead uint32 (n_chunks, S_c//32): the sign
    epilogue packs 32 symbols per word via the shared ``x >= 0`` predicate,
    so unpacking reproduces the f32 symbols bit for bit (DESIGN.md §13).

    ``presparsified=True`` asserts the input is already the top-κ sparse
    vector and skips the selection — the engine's error-feedback path
    computes sparse_κ once for the residual split and feeds it straight
    here (DESIGN.md §11), instead of thresholding the same array twice."""
    phi = cfg.phi(flat.dtype) if phi is None else phi
    gc = flat if flat.ndim == 2 else flat.reshape(-1, cfg.chunk)
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        sparse = gc if presparsified else kops.topk_select(gc, cfg.topk)[0]
        signs = (kops.cs_project_pack(phi, sparse) if cfg.packed
                 else kops.cs_project_sign(phi, sparse))
    else:
        if presparsified:
            sparse = gc
        elif cfg.spmd_topk:
            sparse, _ = topk_sparsify_bisect(gc, cfg.topk,
                                             iters=cfg.bisect_iters)
        else:
            sparse, _ = topk_sparsify(gc, cfg.topk)
        proj = jnp.einsum("sd,nd->ns", phi, sparse)
        signs = pack_signs(proj) if cfg.packed else sign_pm1(proj)
    mags = jnp.linalg.norm(sparse, axis=-1)
    return signs, mags


def reconstruct_chunks(cfg: OBCSAAConfig, y: jnp.ndarray,
                       mags: Optional[jnp.ndarray] = None, phi=None,
                       x0: Optional[jnp.ndarray] = None,
                       return_raw: bool = False):
    """y: (n_chunks, S_c) post-processed aggregate (eq. 13). Decodes via the
    registry (eq. 43; repro.decode) and returns flat (D_pad,).

    ``x0``: warm-start chunks (n_chunks, D_c) from the previous round's RAW
    estimate. ``return_raw=True`` additionally returns that raw (pre-
    magnitude-scaling) estimate so the caller can carry it as next round's
    ``x0`` — warm state must live in decoder space, not gradient space."""
    phi = cfg.phi(y.dtype) if phi is None else phi
    xhat = cs_decode(y, phi, cfg.decode_k, cfg.decode_cfg(), x0=x0)
    raw = xhat
    if cfg.magnitude_tracking and mags is not None:
        norm = jnp.linalg.norm(xhat, axis=-1, keepdims=True)
        xhat = xhat * (mags[:, None] / jnp.maximum(norm, 1e-12))
    flat = xhat.reshape(-1)
    return (flat, raw) if return_raw else flat


# --- simulation mode (paper §V) --------------------------------------------------

def simulate_round(cfg: OBCSAAConfig, grads_flat: jnp.ndarray,
                   k_weights: jnp.ndarray, beta: jnp.ndarray, b_t,
                   h: jnp.ndarray, key, decode_x0=None, noise_var=None,
                   presparsified: bool = False) -> Tuple[jnp.ndarray, dict]:
    """grads_flat: (U, D). Returns (g_hat (D,), diagnostics).

    Implements eq. (6)-(14) with perfect channel inversion: the received
    aggregate is Σ_i K_i b_t β_i C(g_i) + z (eq. 12). ``decode_x0`` warm-
    starts the decoder (eq. 43) with the previous round's raw estimate;
    ``diag["decode_xhat"]`` carries this round's raw estimate back out so
    the FL loop can thread the state (DESIGN.md §9). ``noise_var``
    optionally overrides ``cfg.noise_var`` with a traced value — the FL
    engine's SNR arms axis (DESIGN.md §11) sweeps it without retracing.
    ``presparsified=True`` marks ``grads_flat`` as already top-κ sparse
    per chunk (the engine's fused EF path; see ``compress_chunks``)."""
    U, D = grads_flat.shape
    pad = (-D) % cfg.chunk
    gpad = jnp.pad(grads_flat, ((0, 0), (0, pad)))
    phi = cfg.phi()
    signs, mags = jax.vmap(
        lambda g: compress_chunks(cfg, g, phi,
                                  presparsified=presparsified))(gpad)
    # MAC superposition (eq. 12). The packed codec unpacks to the exact
    # ±1 floats the f32 path produced (shared sign predicate, DESIGN.md
    # §13), so the identical einsum keeps the two paths bit-for-bit equal;
    # the wire-level int32 bit-count MAC lives in the shard-mapped path
    # (collectives.psum_bits_mac).
    symbols = unpack_signs(signs) if cfg.packed else signs
    w = k_weights * beta * b_t                      # (U,)
    y = jnp.einsum("u,ucs->cs", w.astype(symbols.dtype), symbols)
    nv = cfg.noise_var if noise_var is None else noise_var
    noise = chan.draw_noise(key, y.shape, nv)
    y = y + noise                                   # eq. (12)
    denom = jnp.maximum(jnp.sum(k_weights * beta) * b_t, 1e-12)
    y = y / denom                                   # eq. (13)
    mbar = jnp.einsum("u,uc->c", (k_weights * beta).astype(mags.dtype),
                      mags) / jnp.maximum(jnp.sum(k_weights * beta), 1e-12)
    ghat, xraw = reconstruct_chunks(
        cfg, y, mbar if cfg.magnitude_tracking else None, phi,
        x0=decode_x0, return_raw=True)
    diag = {"denom": denom, "mbar_mean": jnp.mean(mbar),
            "y_rms": jnp.sqrt(jnp.mean(y ** 2)), "decode_xhat": xraw}
    return ghat[:D], diag


# --- distributed mode (inside shard_map over worker axes) -------------------------

def shardmap_compress(cfg: OBCSAAConfig, local_flat: jnp.ndarray,
                      worker_axes, *, k_weight, beta_i, b_t, phi=None,
                      wire_dtype=None):
    """Worker-side half, INSIDE shard_map(manual over worker_axes).

    Compress this worker's local gradient (eq. 7), scale by the power
    factor (eq. 10-11), and superpose over the MAC: the psum over
    ``worker_axes`` IS the over-the-air sum (eq. 12). ``wire_dtype``
    optionally narrows the transmitted symbols (±w each), halving wire
    bytes with bf16.

    Returns ``(y, ksum, mag_sum)``: the raw received aggregate, the
    weight normaliser Σ_i K_i β_i, and the weighted magnitude sum (None
    unless ``cfg.magnitude_tracking``) — everything the PS-side
    ``shardmap_reconstruct`` needs.

    With ``cfg.packed`` the wire carries uint32 words (32 signs each) and
    the superposition is the exact int32 bit-count MAC
    (``collectives.psum_bits_mac``): y = K·b_t · Σ_i β_i·(2·bit_i − 1),
    assuming the worker-uniform K_i·b_t of the shard-mapped trainer
    (equal-sized shards; DESIGN.md §13). ``wire_dtype`` is ignored on the
    packed path — the symbols are already 1-bit."""
    signs, mags = compress_chunks(cfg, local_flat, phi)
    return shardmap_mac(cfg, signs, mags, worker_axes, k_weight=k_weight,
                        beta_i=beta_i, b_t=b_t, wire_dtype=wire_dtype)


def shardmap_mac(cfg: OBCSAAConfig, signs, mags, worker_axes, *, k_weight,
                 beta_i, b_t, wire_dtype=None):
    """MAC superposition of one worker's ALREADY-compressed symbols
    (eq. 12), INSIDE shard_map(manual over worker_axes).

    Split out of ``shardmap_compress`` so callers that produce their signs
    in blocks — the sharded zoo round's ``lax.map``-chunked compression at
    ≥1B parameters (engine/zoo.py, DESIGN.md §14) — superpose through the
    identical wire path: exact int32 ``psum_bits_mac`` when ``cfg.packed``,
    f32 symbol psum otherwise. Returns ``(y, ksum, mag_sum)`` exactly like
    ``shardmap_compress``."""
    if cfg.packed:
        s_int = coll.psum_bits_mac(signs, worker_axes, beta_i=beta_i)
        y = s_int.astype(jnp.float32) * (k_weight * b_t)  # eq. (12)
    else:
        wd = wire_dtype or signs.dtype
        w = (k_weight * beta_i * b_t).astype(wd)
        y = coll.psum(signs.astype(wd) * w, worker_axes)    # eq. (12)
    ksum = coll.psum(k_weight * beta_i, worker_axes)
    mag_sum = (coll.psum(mags * (k_weight * beta_i).astype(mags.dtype),
                         worker_axes)
               if cfg.magnitude_tracking else None)
    return y, ksum, mag_sum


def shardmap_reconstruct(cfg: OBCSAAConfig, y: jnp.ndarray, ksum,
                         mag_sum=None, *, b_t, noise_key, phi=None,
                         decode_x0=None) -> jnp.ndarray:
    """PS-side half: AWGN + post-processing (eq. 13) + 1-bit CS decode
    (eq. 43, registry-selected via ``cfg.decoder``).

    Noise is added once at the PS — every shard folds the same key, so the
    (replicated) draw is identical and the result stays replicated.
    ``decode_x0`` warm-starts the decoder when the caller carries state."""
    denom = jnp.maximum(ksum * b_t, 1e-12)
    noise = chan.draw_noise(noise_key, y.shape, cfg.noise_var)
    y = (y.astype(jnp.float32) + noise) / denom         # eq. (13)
    mbar = (mag_sum / jnp.maximum(ksum, 1e-12)
            if (cfg.magnitude_tracking and mag_sum is not None) else None)
    return reconstruct_chunks(cfg, y, mbar, phi, x0=decode_x0)


def shardmap_aggregate(cfg: OBCSAAConfig, local_flat: jnp.ndarray,
                       worker_axes, *, k_weight, beta_i, b_t, n_workers: int,
                       noise_key, phi=None) -> jnp.ndarray:
    """Called INSIDE shard_map(manual over worker_axes). local_flat: (D_pad,)
    is this worker's local gradient; returns the reconstructed global
    gradient (identical on all workers, like the PS broadcast)."""
    del n_workers  # implied by worker_axes; kept for call-site stability
    y, ksum, mag_sum = shardmap_compress(cfg, local_flat, worker_axes,
                                         k_weight=k_weight, beta_i=beta_i,
                                         b_t=b_t, phi=phi)
    return shardmap_reconstruct(cfg, y, ksum, mag_sum, b_t=b_t,
                                noise_key=noise_key, phi=phi)


def comm_stats(cfg: OBCSAAConfig, D: int) -> dict:
    """Wire statistics per worker per round (vs uncompressed analog float)."""
    n_chunks = -(-D // cfg.chunk)
    symbols = n_chunks * cfg.measure + (n_chunks if cfg.magnitude_tracking
                                        else 0)
    # packed codec wire accounting (DESIGN.md §13): 1 bit per sign symbol
    # vs 32 for the f32 representation; the per-chunk magnitude scalar
    # stays a 32-bit float in both codecs
    mag_bits = 32 * n_chunks if cfg.magnitude_tracking else 0
    bits_f32 = 32 * n_chunks * cfg.measure + mag_bits
    bits_packed = n_chunks * cfg.measure + mag_bits
    return {
        "D": D,
        "n_chunks": n_chunks,
        "symbols_per_round": symbols,
        "compression_ratio": D / symbols,
        "latency_fraction": symbols / D,   # same-bandwidth transmission time
        "uplink_bits_f32": bits_f32,
        "uplink_bits_packed": bits_packed,
        "packed_wire_ratio": bits_f32 / bits_packed,
    }
