"""Power control (paper eq. 10-11).

p_{i,t} = β_{i,t} K_i b_t / h_{i,t}. Because every transmitted symbol is ±1,
|p_i c_i|² = β_i² K_i² b_t² / h_i² — independent of the gradient. The peak
constraint (11) therefore bounds b_t per worker:

    b_t ≤ h_i √(P_i^Max) / K_i   for every scheduled worker i.

The same caps feed the P2 solvers' b_t* = min scheduled cap (DESIGN.md
§10) and the noise term σ²/(ΣK_iβ_ib_t)² of the Theorem-1 error budget
(repro.theory, DESIGN.md §12).
"""
from __future__ import annotations

import jax.numpy as jnp


def power_factors(beta: jnp.ndarray, k_weights: jnp.ndarray, b_t,
                  h: jnp.ndarray) -> jnp.ndarray:
    """Eq. (10)."""
    return beta * k_weights * b_t / h


def tx_power(beta: jnp.ndarray, k_weights: jnp.ndarray, b_t,
             h: jnp.ndarray) -> jnp.ndarray:
    """Per-worker transmit power |p_i c_i|² (eq. 11, symbol-independent)."""
    return (beta * k_weights * b_t) ** 2 / h ** 2


def max_bt(beta: jnp.ndarray, k_weights: jnp.ndarray, h: jnp.ndarray,
           p_max) -> jnp.ndarray:
    """Largest b_t satisfying (11) for all scheduled workers."""
    per_worker = h * jnp.sqrt(jnp.asarray(p_max, jnp.float32)) / k_weights
    # unscheduled workers impose no constraint
    caps = jnp.where(beta > 0, per_worker, jnp.inf)
    return jnp.min(caps)


def feasible(beta: jnp.ndarray, k_weights: jnp.ndarray, b_t,
             h: jnp.ndarray, p_max) -> jnp.ndarray:
    # relative slack: b_t on the exact boundary must test feasible in f32
    return jnp.all(tx_power(beta, k_weights, b_t, h)
                   <= p_max * (1.0 + 1e-5) + 1e-9)
