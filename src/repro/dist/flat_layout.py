"""Model-major sharded-flat parameter layout for zoo-scale training
(DESIGN.md §16).

The zoo round (engine/zoo.py) stores parameters as a chunked
``(n_chunks, D_c)`` f32 array whose chunk axis is partitioned model-major
over ``("model",) + worker_axes``. For REAL gradients to flow into the
compressor with no host round-trip and no full-D gather, the flat order
of that array cannot be arbitrary: it must be chosen so that the
gradients each device computes are EXACTLY the chunk rows it owns.

:class:`FlatShardLayout` pins that order down. With ``mp`` model shards,
the canonical flat vector is the concatenation of ``mp`` *sections*; the
m-th section is, leaf by leaf (pytree flatten order), the raveled m-th
slice of each leaf along its model-sharded dim (``dist.sharding
.param_shard_dims``), zero-padded at the section end to a whole number of
chunks (``n_half``, rounded up so the worker count divides it). The chunk
rows of section m are the rows device column m owns — so

* a worker column all-gathers its section over the worker axes and turns
  it into per-leaf weight SHARDS by local reshapes (``section_to_tree``),
* the backward pass produces cotangents with those same shard shapes, and
  flattening them back (``tree_to_section``) IS the (n_half, D_c) block
  of per-worker gradients the compressor consumes — layout conversion is
  zero communication by construction.

Every leaf must split evenly over ``mp`` along some dim (build raises
naming the offending leaf otherwise); that is what makes the section
structure identical for every m, which in turn is what lets one traced
program serve all model shards.
"""
from __future__ import annotations

import math
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import STACKED_KEYS, param_shard_dims


class _LeafSlot(NamedTuple):
    name: str            # keystr path, for error messages
    shape: Tuple[int, ...]
    dtype: Any
    dim: int             # model-sharded dim (-1: replicated, mp == 1 only)
    offset: int          # element offset of the m-slice within its section
    m_size: int          # elements of one m-slice (= prod(shape) // mp)


class FlatShardLayout:
    """See module docstring. Build via :meth:`build`."""

    def __init__(self, treedef, slots: List[_LeafSlot], *, mp: int,
                 chunk: int, n_half: int):
        self.treedef = treedef
        self.slots = slots
        self.mp = mp
        self.chunk = chunk
        self.n_half = n_half                       # chunks per section
        self.n_chunks = mp * n_half
        self.sec_elems = sum(s.m_size for s in slots)
        self.D = self.sec_elems * mp               # true parameter count
        self.D_pad = self.n_chunks * chunk

    @classmethod
    def build(cls, shapes_tree, mesh, *, chunk: int, gran: int = 1,
              model_axis: str = "model", stacked_keys=STACKED_KEYS):
        """Layout for a params pytree of arrays / ShapeDtypeStructs.

        ``gran``: round ``n_half`` up to a multiple of this (the worker
        count, so every device owns a whole number of chunk rows)."""
        mp = int(dict(mesh.shape).get(model_axis, 1))
        dims_tree = param_shard_dims(shapes_tree, mesh,
                                     model_axis=model_axis,
                                     stacked_keys=stacked_keys)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
        dims = jax.tree_util.tree_leaves(dims_tree)
        slots, off = [], 0
        for (path, leaf), dim in zip(leaves, dims):
            name = jax.tree_util.keystr(path)
            shape = tuple(leaf.shape)
            size = math.prod(shape) if shape else 1
            if mp > 1:
                if dim < 0 or shape[dim] % mp != 0:
                    raise ValueError(
                        f"zoo-train layout: leaf {name} with shape {shape} "
                        f"has no dim divisible by the model-axis size "
                        f"{mp}; every parameter leaf must split evenly "
                        f"over '{model_axis}' (DESIGN.md §16). Resize the "
                        f"offending dimension or shrink the model axis.")
                if size % mp != 0:
                    raise ValueError(
                        f"zoo-train layout: leaf {name} size {size} not "
                        f"divisible by model-axis size {mp}")
            m_size = size // mp
            slots.append(_LeafSlot(name, shape, leaf.dtype, dim, off, m_size))
            off += m_size
        n_half = -(-off // chunk)
        n_half = -(-n_half // max(gran, 1)) * max(gran, 1)
        return cls(treedef, slots, mp=mp, chunk=chunk, n_half=n_half)

    # -- shapes ------------------------------------------------------------

    def shard_shape(self, slot: _LeafSlot) -> Tuple[int, ...]:
        """Shape of one m-slice of ``slot`` (leaf shape with the sharded
        dim divided by mp)."""
        if self.mp == 1 or slot.dim < 0:
            return slot.shape
        s = list(slot.shape)
        s[slot.dim] //= self.mp
        return tuple(s)

    # -- device-local conversions (identical for every m) ------------------

    def section_to_tree(self, sect):
        """(n_half, D_c) or flat m-section -> pytree of per-leaf m-slices
        (pure local reshapes; same structure whatever m — that is the
        layout invariant)."""
        flat = sect.reshape(-1)
        leaves = [
            jax.lax.dynamic_slice_in_dim(flat, s.offset, s.m_size, 0)
            .reshape(self.shard_shape(s)) for s in self.slots]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def tree_to_section(self, slices_tree):
        """pytree of per-leaf m-slices -> (n_half, D_c) flat m-section,
        zero-padded; dtype follows the input leaves."""
        leaves = jax.tree_util.tree_leaves(slices_tree)
        flat = jnp.concatenate([x.reshape(-1) for x in leaves])
        pad = self.n_half * self.chunk - flat.shape[0]
        return jnp.pad(flat, (0, pad)).reshape(self.n_half, self.chunk)

    # -- full-tree conversions (init / oracle / checkpoint interop) --------

    def _slice_m(self, leaf, slot: _LeafSlot, m: int):
        if self.mp == 1 or slot.dim < 0:
            return leaf
        k = slot.shape[slot.dim] // self.mp
        return jax.lax.slice_in_dim(leaf, m * k, (m + 1) * k, axis=slot.dim)

    def tree_to_master(self, params, dtype=jnp.float32):
        """Full params pytree -> the canonical (n_chunks, D_c) array."""
        leaves = jax.tree_util.tree_leaves(params)
        sections = []
        for m in range(self.mp):
            flat = jnp.concatenate(
                [self._slice_m(leaf, s, m).reshape(-1).astype(dtype)
                 for leaf, s in zip(leaves, self.slots)])
            pad = self.n_half * self.chunk - flat.shape[0]
            sections.append(jnp.pad(flat, (0, pad)))
        return jnp.concatenate(sections).reshape(self.n_chunks, self.chunk)

    def master_to_tree(self, master, dtype=None):
        """(n_chunks, D_c) -> full params pytree (inverse of
        ``tree_to_master``; pad elements are dropped). ``dtype`` casts the
        leaves (None keeps the master's dtype)."""
        flat = master.reshape(self.mp, self.n_half * self.chunk)
        if dtype is not None:
            flat = flat.astype(dtype)
        per_m = [jax.tree_util.tree_leaves(self.section_to_tree(flat[m]))
                 for m in range(self.mp)]
        leaves = []
        for i, s in enumerate(self.slots):
            if self.mp == 1 or s.dim < 0:
                leaves.append(per_m[0][i])
            else:
                leaves.append(jnp.concatenate(
                    [per_m[m][i] for m in range(self.mp)], axis=s.dim))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)
