"""Distributed execution substrate.

``repro.dist`` is the layer between the paper's math (``repro.core``) and
physical meshes (``repro.launch.mesh``):

- ``repro.dist.sharding`` — mesh-aware sharding-constraint + inference
  helpers (``constrain``, ``best_spec``, ``infer_param_sharding``) used by
  every model family and by the step builders.
- ``repro.dist.collectives`` — worker-axis collectives. The over-the-air
  MAC superposition (paper eq. 8-12) IS ``psum`` over the mesh axes that
  enumerate FL workers (DESIGN.md §3).
- ``repro.dist.compat`` — forward-compat shims: the codebase is written
  against the jax>=0.6 sharding surface (``jax.shard_map``,
  ``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``); on older jax
  those names are backported here. Installed on import, idempotent.
"""
from repro.dist import compat as _compat

_compat.install()

from repro.dist import collectives  # noqa: E402
from repro.dist.sharding import (best_spec, constrain,  # noqa: E402
                                 infer_param_sharding, param_shard_dims)

__all__ = ["best_spec", "collectives", "constrain", "infer_param_sharding",
           "param_shard_dims"]
