"""Forward-compat shims for the jax>=0.6 sharding API on older jax.

The repo targets the modern surface — ``jax.shard_map(f, mesh=...,
axis_names={...}, check_vma=...)``, ``with jax.set_mesh(mesh): ...`` and
``jax.sharding.get_abstract_mesh()`` — but must also run on jax 0.4.x where
``shard_map`` lives in ``jax.experimental`` (with ``auto``/``check_rep``
instead of ``axis_names``/``check_vma``) and the other two names don't
exist at all. ``install()`` fills exactly the missing names; on a jax that
already has them it does nothing.

The shims keep two pieces of thread-local state that old jax has no query
for: the ambient mesh (entered via ``set_mesh``) and the set of axis names
currently manual because tracing happens inside a ``shard_map`` body. Both
are consumed by ``repro.dist.sharding.constrain`` and by the MoE dispatch's
``get_abstract_mesh().axis_types`` probe.
"""
from __future__ import annotations

import threading
from functools import wraps

import jax

_TLS = threading.local()

# True when install() had to backport shard_map (jax 0.4.x). The legacy
# SPMD partitioner aborts on sharding constraints inside a partial-manual
# shard_map body (manual-subgroup mismatch), so `constrain` must degrade
# to a no-op there; native jax.shard_map handles them fine.
LEGACY_SHARD_MAP = False


def _manual_stack():
    stack = getattr(_TLS, "manual", None)
    if stack is None:
        stack = []
        _TLS.manual = stack
    return stack


def current_mesh():
    """The mesh entered via ``set_mesh`` in this thread, or None."""
    return getattr(_TLS, "mesh", None)


def manual_axis_names() -> frozenset:
    """Axis names manual in the current trace (inside shard_map bodies)."""
    out = set()
    for s in _manual_stack():
        out |= s
    return frozenset(out)


class _SetMesh:
    """Return object of the ``set_mesh`` shim.

    Like real ``jax.set_mesh``, the ambient mesh is set EAGERLY at call
    time, so the plain statement form ``jax.set_mesh(mesh)`` works. Using
    it as a context manager additionally enters the legacy ``Mesh``
    context (bare-PartitionSpec constraints on 0.4.x) and restores the
    previous ambient mesh on exit."""

    def __init__(self, mesh):
        self._prev = getattr(_TLS, "mesh", None)
        self._mesh = mesh
        _TLS.mesh = mesh

    def __enter__(self):
        if self._mesh is not None:
            self._mesh.__enter__()
        return self._mesh

    def __exit__(self, *exc):
        if self._mesh is not None:
            self._mesh.__exit__(*exc)
        _TLS.mesh = self._prev
        return False


def set_mesh(mesh):
    """Backport of ``jax.set_mesh`` (statement and context-manager forms)."""
    return _SetMesh(mesh)


class _AbstractMeshView:
    """Duck-type of ``jax.sharding.AbstractMesh`` for jax 0.4.x.

    Exposes the attributes the codebase reads (``empty``, ``axis_names``,
    ``shape``, ``axis_types``) plus ``_mesh`` so the ``shard_map`` shim can
    unwrap it back to the concrete Mesh."""

    def __init__(self, mesh, manual=frozenset()):
        self._mesh = mesh
        self._manual = frozenset(manual)

    @property
    def empty(self):
        return self._mesh is None or not self._mesh.axis_names

    @property
    def axis_names(self):
        return self._mesh.axis_names if self._mesh is not None else ()

    @property
    def shape(self):
        return self._mesh.shape if self._mesh is not None else {}

    @property
    def axis_types(self):
        return tuple("Manual" if a in self._manual else "Auto"
                     for a in self.axis_names)

    def __repr__(self):
        return f"_AbstractMeshView({self._mesh!r}, manual={set(self._manual)})"


def get_abstract_mesh():
    """Backport of ``jax.sharding.get_abstract_mesh``."""
    return _AbstractMeshView(current_mesh(), manual_axis_names())


def _unwrap(mesh):
    return getattr(mesh, "_mesh", mesh)


def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
              axis_names=None, check_vma=None, check_rep=None, auto=None):
    """Backport of ``jax.shard_map`` onto ``jax.experimental.shard_map``.

    ``axis_names`` (modern: axes that are MANUAL) selects partial-auto
    mode natively; the 0.4.x SPMD partitioner aborts on several ops inside
    partial-manual bodies ("Check failed: ...IsManualSubgroup..."), so the
    legacy lowering goes FULL manual instead: axes the caller wanted auto
    are left unmentioned by the in/out specs and therefore replicated.
    That is numerically identical for bodies that only issue collectives
    over their manual axes (all in-repo bodies) — it just forgoes
    model-axis auto-partitioning inside the body on old jax. ``check_vma``
    maps to ``check_rep``. The wrapped body pushes every mesh axis onto
    the manual thread-local so ``constrain`` (a no-op for manual axes) and
    the MoE dispatch's axis probe see them during tracing."""
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    mesh = _unwrap(mesh) if mesh is not None else _unwrap(current_mesh())
    if mesh is None:
        raise ValueError("shard_map: no mesh passed and no ambient mesh set")
    all_axes = set(mesh.axis_names)
    del axis_names, auto  # legacy lowering is full-manual, see docstring
    if check_vma is None:
        check_vma = True if check_rep is None else check_rep

    @wraps(f)
    def body(*args, **kwargs):
        stack = _manual_stack()
        stack.append(frozenset(all_axes))
        try:
            return f(*args, **kwargs)
        finally:
            stack.pop()

    return _legacy_shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=bool(check_vma))


def install():
    """Fill missing modern names on the ``jax`` namespace (idempotent)."""
    global LEGACY_SHARD_MAP
    if not hasattr(jax, "shard_map"):
        LEGACY_SHARD_MAP = True
        jax.shard_map = shard_map
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_abstract_mesh
