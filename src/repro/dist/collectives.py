"""Worker-axis collectives — the over-the-air MAC primitives.

The paper's analog superposition (eq. 8-12) is not *modeled by* a psum, it
*is* the psum over the mesh axes that enumerate FL workers (DESIGN.md §3):
every worker transmits its power-scaled ±1 measurement symbols and the
multiple-access channel adds them. ``obcsaa.shardmap_compress`` /
``shardmap_reconstruct`` call through these wrappers so the identical code
runs on the 2-axis ``(data, model)`` host mesh and the 3-axis
``(pod, data, model)`` production mesh.

All wrappers normalise the axis argument (str | tuple | empty) and treat
"no worker axes" as a single-worker federation: ``psum`` is then the
identity, ``axis_index`` 0, ``axis_size`` 1 — which makes the unit tests
and the single-host simulation exercise the same call sites.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def norm_axes(axes) -> tuple:
    """Normalise an axis argument to a tuple of names."""
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def _one_or_tuple(axes: tuple):
    return axes if len(axes) > 1 else axes[0]


def psum(x, axes):
    """Sum over the worker axes: the MAC superposition (eq. 12)."""
    axes = norm_axes(axes)
    if not axes:
        return x
    return jax.lax.psum(x, _one_or_tuple(axes))


def pmean(x, axes):
    axes = norm_axes(axes)
    if not axes:
        return x
    return jax.lax.pmean(x, _one_or_tuple(axes))


def all_gather(x, axes, *, axis: int = 0, tiled: bool = False):
    """Gather per-worker values (digital-baseline aggregation / debugging —
    the analog path never needs it; see DESIGN.md §3)."""
    axes = norm_axes(axes)
    if not axes:
        return x if tiled else jnp.expand_dims(x, axis)
    return jax.lax.all_gather(x, _one_or_tuple(axes), axis=axis, tiled=tiled)


def axis_index(axes):
    """This worker's linear index over the (possibly compound) worker axes."""
    axes = norm_axes(axes)
    if not axes:
        return jnp.zeros((), jnp.int32)
    return jax.lax.axis_index(_one_or_tuple(axes))


def axis_size(axes, mesh=None) -> int:
    """Static worker count, from the mesh when given else the trace env."""
    axes = norm_axes(axes)
    if not axes:
        return 1
    if mesh is not None:
        n = 1
        for ax in axes:
            n *= dict(mesh.shape)[ax]
        return n
    n = 1
    for ax in axes:
        n *= jax.lax.psum(1, ax)
    return n
