"""Worker-axis collectives — the over-the-air MAC primitives.

The paper's analog superposition (eq. 8-12) is not *modeled by* a psum, it
*is* the psum over the mesh axes that enumerate FL workers (DESIGN.md §3):
every worker transmits its power-scaled ±1 measurement symbols and the
multiple-access channel adds them. ``obcsaa.shardmap_compress`` /
``shardmap_reconstruct`` call through these wrappers so the identical code
runs on the 2-axis ``(data, model)`` host mesh and the 3-axis
``(pod, data, model)`` production mesh.

All wrappers normalise the axis argument (str | tuple | empty) and treat
"no worker axes" as a single-worker federation: ``psum`` is then the
identity, ``axis_index`` 0, ``axis_size`` 1 — which makes the unit tests
and the single-host simulation exercise the same call sites.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def norm_axes(axes) -> tuple:
    """Normalise an axis argument to a tuple of names."""
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def _one_or_tuple(axes: tuple):
    return axes if len(axes) > 1 else axes[0]


def psum(x, axes):
    """Sum over the worker axes: the MAC superposition (eq. 12)."""
    axes = norm_axes(axes)
    if not axes:
        return x
    return jax.lax.psum(x, _one_or_tuple(axes))


def psum_bits_mac(packed, axes, *, beta_i=None):
    """MAC superposition of PACKED 1-bit symbols (eq. 12, DESIGN.md §13).

    ``packed``: uint32 (..., S//32), 32 signs per word (kernels/sign.py
    codec). Each worker's per-lane contribution is β·(2·bit − 1) ∈
    {−1, 0, +1} — per word that is the popcount identity
    Σ_lanes sign = 2·popcount(w) − 32 — accumulated EXACTLY as int32
    across the worker axes: integer superposition has no f32 rounding, so
    the scaled result matches the f32 symbol psum bit for bit whenever
    the (worker-uniform) power scale K·b_t makes ``scale·m`` exactly
    representable. Returns the int32 per-lane signed sum (..., S); the
    caller applies the uniform ``K·b_t`` scale AFTER the sum — per-worker
    weights need the f32 wire."""
    from repro.kernels.sign import unpack_bits
    contrib = 2 * unpack_bits(packed, jnp.int32) - 1
    if beta_i is not None:
        contrib = contrib * beta_i.astype(jnp.int32)
    return psum(contrib, axes)


def shard_slice(x, axes, *, axis: int = 0):
    """This worker's equal block of a replicated array — the dual of
    ``all_gather(tiled=True)``.

    Slices ``[idx·n, (idx+1)·n)`` along ``axis`` where
    ``idx = axis_index(axes)`` and ``n = shape[axis] // axis_size(axes)``.
    The sharded zoo round (engine/zoo.py, DESIGN.md §14) uses it to split
    the post-MAC decode across the worker axes: ``y`` comes out of the
    superposition replicated over workers, and each device reconstructs
    only the chunk block whose parameters it owns. No worker axes → the
    whole array (single-worker federation)."""
    axes = norm_axes(axes)
    if not axes:
        return x
    n = x.shape[axis] // axis_size(axes)
    idx = axis_index(axes)
    return jax.lax.dynamic_slice_in_dim(x, idx * n, n, axis)


def pmean(x, axes):
    axes = norm_axes(axes)
    if not axes:
        return x
    return jax.lax.pmean(x, _one_or_tuple(axes))


def all_gather(x, axes, *, axis: int = 0, tiled: bool = False):
    """Gather per-worker values (digital-baseline aggregation / debugging —
    the analog path never needs it; see DESIGN.md §3)."""
    axes = norm_axes(axes)
    if not axes:
        return x if tiled else jnp.expand_dims(x, axis)
    return jax.lax.all_gather(x, _one_or_tuple(axes), axis=axis, tiled=tiled)


def replicated_gather(axes, group_size: int, *, dim: int = 0):
    """All-gather whose TRANSPOSE is this device's slice — the collective
    behind the zoo-train layer resolver (DESIGN.md §16).

    Forward: tiled ``all_gather`` of a weight shard along ``dim`` over
    ``axes`` (the model axis), producing the full weight for redundant
    compute. Backward: because every device in the gather group runs the
    SAME forward on the SAME batch, their cotangents are bit-identical
    replicas — so the exact adjoint is a LOCAL static slice, not the
    AD-default ``psum_scatter`` (which would sum ``axis_size`` identical
    copies and scale gradients by the group size, besides introducing a
    cross-device float reduction that breaks bitwise mesh-invariance).

    Returns a unary ``gather(x)`` for static ``(axes, group_size, dim)``;
    ``group_size`` is the static device count over ``axes`` (the slice
    size in the adjoint must be static). No axes → identity."""
    axes = norm_axes(axes)
    if not axes:
        return lambda x: x

    @jax.custom_vjp
    def gather(x):
        return jax.lax.all_gather(x, _one_or_tuple(axes), axis=dim,
                                  tiled=True)

    def fwd(x):
        return gather(x), None

    def bwd(_, g):
        n_local = g.shape[dim] // group_size
        idx = axis_index(axes)
        return (jax.lax.dynamic_slice_in_dim(g, idx * n_local, n_local,
                                             dim),)

    gather.defvjp(fwd, bwd)
    return gather


def axis_index(axes):
    """This worker's linear index over the (possibly compound) worker axes."""
    axes = norm_axes(axes)
    if not axes:
        return jnp.zeros((), jnp.int32)
    return jax.lax.axis_index(_one_or_tuple(axes))


def axis_size(axes, mesh=None) -> int:
    """Static worker count, from the mesh when given else the trace env."""
    axes = norm_axes(axes)
    if not axes:
        return 1
    if mesh is not None:
        n = 1
        for ax in axes:
            n *= dict(mesh.shape)[ax]
        return n
    n = 1
    for ax in axes:
        n *= jax.lax.psum(1, ax)
    return n
