"""Mesh-aware sharding: soft constraints + spec/pytree inference.

Three entry points, consumed across core, models, and launch:

- ``constrain(x, axes)`` — ``with_sharding_constraint`` that degrades to a
  no-op when there is no ambient mesh, an axis is absent/manual, or a dim
  isn't divisible. Model code calls it unconditionally; the same forward
  runs unsharded on one CPU device and sharded on the production mesh.
- ``best_spec(shape, hints, mesh)`` — per-dim axis choice from priority
  hint lists like ``["data", None]``, preferring the largest divisible
  option and falling back to replication.
- ``infer_param_sharding(tree, mesh)`` — pytree-wide ``NamedSharding``
  inference for params / optimizer state: the largest model-divisible dim
  of each leaf is sharded over ``model``; worker axes (pod, data) stay
  replicated because every FL worker holds the full model (DESIGN.md §3).
  Stacked-layer pytrees (leaves whose path goes through a
  ``stacked_keys`` entry, e.g. the transformer's ``layers`` collection
  scanned by ``lax.scan``) never shard their leading dim: that axis is
  the scan axis and must stay whole so ``lax.scan`` can slice one layer
  per step (DESIGN.md §16).
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat

# Pytree keys whose subtrees hold layer-stacked leaves: dim 0 is the
# lax.scan axis, not a shardable weight dim.
STACKED_KEYS = ("layers", "enc_layers")


def _ambient():
    """(concrete mesh or None, frozenset of manual axis names)."""
    view = jax.sharding.get_abstract_mesh()
    if view is None or getattr(view, "empty", True):
        return None, frozenset()
    manual = frozenset(a for a, t in zip(view.axis_names, view.axis_types)
                       if "Manual" in str(t))
    return compat._unwrap(view), manual


def _axis_sizes(mesh) -> dict:
    return dict(mesh.shape)


def constrain(x, axes):
    """Constrain ``x`` to ``axes`` (one entry per dim: axis name, tuple of
    names, or None) on the ambient mesh; no-op when that is impossible.

    Skipped per-name: names not in the mesh, names already manual (an
    enclosing ``shard_map`` owns them), names already used on an earlier
    dim, and names whose size doesn't divide the dim."""
    shape = getattr(x, "shape", None)
    if shape is None:
        return x
    mesh, manual = _ambient()
    if mesh is None:
        return x
    if manual and compat.LEGACY_SHARD_MAP:
        # 0.4.x SPMD partitioner aborts on constraints inside a
        # partial-manual shard_map body; drop the hint there.
        return x
    sizes = _axis_sizes(mesh)
    axes = tuple(axes)[:len(shape)]
    axes = axes + (None,) * (len(shape) - len(axes))
    used = set()
    parts = []
    for dim, hint in zip(shape, axes):
        cand = tuple(hint) if isinstance(hint, (tuple, list)) else (hint,)
        keep = []
        stride = 1
        for name in cand:
            if (name and name in sizes and name not in manual
                    and name not in used and dim % (stride * sizes[name]) == 0):
                keep.append(name)
                stride *= sizes[name]
        used.update(keep)
        parts.append(tuple(keep) if len(keep) > 1
                     else (keep[0] if keep else None))
    if all(p is None for p in parts):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts)))
    except Exception:
        # e.g. a constraint the current shard_map/jit context can't express;
        # a sharding hint must never turn into a hard failure.
        return x


def best_spec(shape: Sequence[int], hints, mesh) -> P:
    """Pick a PartitionSpec for ``shape`` from per-dim hint candidates.

    ``hints[i]`` is an axis name, None, or a priority list of candidates.
    For each dim the first candidate that exists in the mesh, is unused,
    and divides the dim wins; the ``data`` hint is widened to the full
    worker-axis product ``("pod", "data")`` on 3-axis meshes when that
    larger factor still divides (global batch is sharded over ALL workers,
    DESIGN.md §3). No candidate fits -> the dim is replicated."""
    mesh = compat._unwrap(mesh)
    sizes = _axis_sizes(mesh)
    used = set()
    parts = []
    for i, dim in enumerate(shape):
        hint = hints[i] if i < len(hints) else None
        cands = list(hint) if isinstance(hint, (list, tuple)) else [hint]
        chosen = None
        for cand in cands:
            if cand is None:
                break
            options = [(cand,)]
            if cand == "data" and "pod" in sizes:
                options.insert(0, ("pod", "data"))
            for opt in options:
                if any(a not in sizes or a in used for a in opt):
                    continue
                total = 1
                for a in opt:
                    total *= sizes[a]
                if dim % total == 0:
                    chosen = opt
                    break
            if chosen:
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    return P(*parts)


def infer_batch_sharding(tree, mesh, *, dim: int = 0):
    """NamedSharding pytree for an (A, ...)-stacked sweep carry/arms tree:
    shard dim ``dim`` of every leaf over the worker axes (via
    ``best_spec``'s ``data`` hint, which widens to ``("pod", "data")`` on
    3-axis meshes) when the arm count divides, replicate otherwise.

    The engine's vmapped arms are embarrassingly parallel over the arm
    axis — no cross-arm collectives — so arm-sharded placement turns the
    sweep into per-device lane groups (DESIGN.md §14). Scalars and
    non-divisible leaves replicate, which is always correct."""
    mesh = compat._unwrap(mesh)

    def spec_of(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) <= dim:
            return NamedSharding(mesh, P())
        hints = [None] * len(shape)
        hints[dim] = "data"
        return NamedSharding(mesh, best_spec(shape, hints, mesh))

    return jax.tree_util.tree_map(spec_of, tree)


def _path_is_stacked(path, stacked_keys) -> bool:
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key in stacked_keys:
            return True
    return False


def _best_model_dim(shape, msize, *, skip_leading: bool):
    """Index of the largest ``msize``-divisible dim, or None.

    ``skip_leading`` excludes dim 0 (a stacked leaf's scan axis). Ties go
    to the trailing dim — the contraction/output dim of weight matrices."""
    if msize <= 1 or not shape:
        return None
    best = None
    for i, d in enumerate(shape):
        if skip_leading and i == 0:
            continue
        if d > 1 and d % msize == 0 and (best is None or d >= shape[best]):
            best = i
    return best


def param_shard_dims(tree, mesh, *, model_axis: str = "model",
                     stacked_keys: Sequence[str] = STACKED_KEYS):
    """Per-leaf shard-dim pytree mirroring ``infer_param_sharding``.

    Each leaf maps to the int dim index sharded over ``model_axis``, or
    -1 when the leaf replicates (-1 rather than None so the result stays
    leaf-for-leaf congruent with ``tree``). Consumed by the zoo-train
    layout and layer resolvers, which need the raw dim to slice/gather
    along rather than a NamedSharding."""
    mesh = compat._unwrap(mesh)
    msize = _axis_sizes(mesh).get(model_axis, 1)

    def dim_of(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        best = _best_model_dim(
            shape, msize, skip_leading=_path_is_stacked(path, stacked_keys))
        return -1 if best is None else best

    return jax.tree_util.tree_map_with_path(dim_of, tree)


def infer_param_sharding(tree, mesh, *, model_axis: str = "model",
                         stacked_keys: Sequence[str] = STACKED_KEYS):
    """NamedSharding pytree for params / optimizer state.

    Rule: shard each leaf's largest ``model``-divisible dim over the model
    axis (ties -> the trailing dim, the contraction/output dim of weight
    matrices); everything else — scalars, odd-shaped leaves, meshes with
    no model parallelism — replicates. Worker axes are never used: each
    data shard is an FL worker holding the full (model-sharded) network.

    Leaves under a ``stacked_keys`` path (layer stacks stepped by
    ``lax.scan``) keep dim 0 whole — the scan axis is sliced one layer per
    step and sharding it would split layers across devices instead of
    splitting weights within a layer."""
    mesh = compat._unwrap(mesh)
    msize = _axis_sizes(mesh).get(model_axis, 1)

    def spec_of(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        best = _best_model_dim(
            shape, msize, skip_leading=_path_is_stacked(path, stacked_keys))
        if best is None:
            return NamedSharding(mesh, P())
        parts = [None] * len(shape)
        parts[best] = model_axis
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_of, tree)
