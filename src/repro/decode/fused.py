"""Fused-Pallas IHT hot loop (eq. 43) — the PS-side decode at kernel speed.

Each IHT iteration is three kernel launches with no HBM round-trip of the
dense intermediates (DESIGN.md §9 fusion boundary):

  1. ``cs_project(mode="residual")``   r = ŷ − x Φᵀ   (projection + residual
     fused in the matmul epilogue — the (n, S) projection never leaves VMEM)
  2. ``backproject``                   x' = x + τ r Φ  (update fused in the
     matmul epilogue — x read once, written once)
  3. ``topk_select``                   x = η_κ(x')     (sort-free bisection
     threshold, vector-unit only)

Tiling policy: on TPU the kernels use their MXU/VMEM module tiles and lower
through Mosaic; on CPU (``interpret=True``) full-extent contraction tiles
are passed instead, so each kernel performs ONE ``dot_general`` identical
to the einsum reference — the fused loop then matches ``repro.decode.iht``
bit for bit (tests/test_decode.py) while staying no slower than the einsum
path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import backproject as _bp
from repro.kernels import cs_project as _cs
from repro.kernels import topk_select as _tk
from repro.kernels import ops as kops
from repro.kernels.ops import _interpret
from repro.kernels.sign import unpack_signs


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _pad_rows(x, n_pad):
    if x.shape[0] == n_pad:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((n_pad - x.shape[0],) + x.shape[1:], x.dtype)])


def fused_iht(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 10,
              tau: float = 1.0, x0=None, interpret=None) -> jnp.ndarray:
    """IHT with the inner iteration fused through the Pallas kernels.

    y: (n, S) post-processed aggregate (eq. 13); phi: (S, D); returns
    (n, D). Semantics are identical to ``repro.decode.iht.iht`` with the
    bisection hard threshold; ``x0`` warm-starts the iterate."""
    interpret = _interpret() if interpret is None else interpret
    n, s = y.shape
    d = phi.shape[1]
    if interpret:
        # full-extent tiles: one dot per kernel, bit-parity with einsum
        bn = _round_up(n, 8)
        proj_tiles, bp_tiles, tk_bn = (bn, s, d), (bn, d, s), bn
    else:
        # module-default tiles: each kernel picks min(its BN, bn), so the
        # padded row count must divide by whatever they pick — any multiple
        # of 8 works below the smallest BN, otherwise pad to the largest BN
        max_bn = max(_cs.BN, _bp.BN, _tk.BN)
        bn = _round_up(n, 8)
        if bn > min(_cs.BN, _bp.BN, _tk.BN):
            bn = _round_up(n, max_bn)
        proj_tiles = bp_tiles = None
        tk_bn = None
    yp = _pad_rows(y, bn)
    if x0 is None:
        xp = jnp.zeros((bn, d), y.dtype)
    else:
        xp = _pad_rows(x0.astype(y.dtype), bn)

    def step(x, _):
        resid = _cs.project(phi, x, mode="residual", y=yp,
                            interpret=interpret, tiles=proj_tiles)
        x = _bp.backproject(x, resid, phi, tau, interpret=interpret,
                            tiles=bp_tiles)
        x, _ = _tk.topk_select(x, k, interpret=interpret, bn=tk_bn)
        return x, None

    x, _ = jax.lax.scan(step, xp, None, length=iters)
    return x[:n]


def fused_biht_packed(y_packed: jnp.ndarray, phi: jnp.ndarray, k: int,
                      iters: int = 30, tau: float = 1.0,
                      interpret=None) -> jnp.ndarray:
    """BIHT on PACKED ±1 measurements — the packed 1-bit decode loop
    (DESIGN.md §13).

    y_packed: uint32 (n, S//32) from ``ops.cs_project_pack`` (or the
    packed MAC); phi: (S, D); unit-norm rows out, like ``ops.biht``.

    Each iteration runs the packed kernel pair with the modules' real
    (non-full-extent) VMEM tiles: ``cs_project(mode="pack_sign_residual")``
    consumes the fresh sign vector in-kernel and emits the two uint32
    residual bit-planes; ``backproject_packed`` unpacks them in-tile to
    the exact {−2, 0, +2} floats of the f32 residual. Same values through
    the same ``dot_general`` tilings ⇒ bit-for-bit equal to ``ops.biht``
    on the unpacked measurements, at 1/32 the measurement bytes and 1/16
    the residual bytes through HBM. The one dense unpack is the x0 seed
    (once, outside the loop)."""
    interpret = _interpret() if interpret is None else interpret
    S = phi.shape[0]
    y_f = unpack_signs(y_packed, phi.dtype)          # x0 seed only
    x0 = kops.backproject(
        jnp.zeros((y_packed.shape[0], phi.shape[1]), phi.dtype), y_f, phi,
        1.0 / S, interpret=interpret)
    x, _ = kops.topk_select(x0, k, interpret=interpret)

    def step(x, _):
        plus, minus = kops.cs_pack_sign_residual(phi, x, y_packed,
                                                 interpret=interpret)
        x = kops.backproject_packed(x, plus, minus, phi, tau / S,
                                    interpret=interpret)
        x, _ = kops.topk_select(x, k, interpret=interpret)
        return x, None

    x, _ = jax.lax.scan(step, x, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)
