"""1-bit CS decoder family — einsum reference implementations (eq. 43).

The PS solves  min ||x||_1  s.t. ||ŷ − Φx||² ≤ ε  (eq. 43). This module is
the iterative-hard-thresholding family the paper selects (BIHT, Jacques et
al.), plus the adaptive-step and warm-start variants the registry exposes
(DESIGN.md §9):

- ``iht``: x ← η_κ(x + τ Φᵀ(ŷ − Φx)) on the REAL post-processed aggregate ŷ
  (the paper's analysis, eq. 42-44, treats the 1-bit error as bounded noise
  on real measurements).
- ``niht``: normalized IHT (Blumensath & Davies 2010) — the step size is
  recomputed every iteration as μ = ||g||²/||Φg||² with g the gradient
  restricted to the current support, removing the fixed-τ tuning knob.
- ``biht_sign``: the classic single-worker BIHT with sign-consistency
  updates x ← η_κ(x + (τ/S) Φᵀ(y_sign − sign(Φx))), unit-normalized.

All decoders accept ``x0``, the warm-start iterate: round *t* of the FL
loop can seed the decode with round *t−1*'s estimate, exploiting temporal
gradient correlation (DESIGN.md §9; state handling lives in
``repro.fl.rounds``). ``x0=None`` is the cold start from zeros (``iht``)
or from the thresholded back-projection (``biht_sign``).

Magnitude note: sign measurements are scale-invariant, so the decoders
recover direction; the aggregator transmits one extra analog scalar per
worker (the sparsified-gradient norm) to restore scale — standard "norm
estimation" in the 1-bit CS literature, recorded in DESIGN.md §4.

These are the allclose/bitwise oracles for the fused-Pallas hot loop in
``repro.decode.fused``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import sign_pm1


def hard_threshold(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """η_κ: keep the k largest-|.| entries along the last axis (eq. 6).
    Mask scattered from ``lax.top_k`` indices — exactly k survivors, ties
    broken by value order then lowest index (see
    ``core.sparsify.topk_sparsify`` for the cumsum-fusion perf note)."""
    absx = jnp.abs(x)
    _, idx = jax.lax.top_k(absx, k)
    mask = jnp.zeros(x.shape, bool)
    mask = jnp.put_along_axis(mask, idx, True, axis=-1, inplace=False)
    return x * mask


def hard_threshold_bisect(x: jnp.ndarray, k: int,
                          iters: int = 40) -> jnp.ndarray:
    """η_κ via magnitude-threshold bisection — the SPMD-partitionable
    variant (``jax.lax.top_k`` lowers to a sort GSPMD cannot shard).
    ``iters`` is the threshold resolution budget (max·2^-iters)."""
    from repro.core.sparsify import topk_sparsify_bisect  # lazy: decode
    # never imports repro.core at module scope (core imports decode)
    return topk_sparsify_bisect(x, k, iters=iters)[0]


#: Divergence edge for the fixed-step update x ← η_κ(x + τΦᵀ(y − Φx)):
#: on the iterate support the map is I − τΦ_TᵀΦ_T, whose spectrum stays in
#: (−1, 1] iff τ·λ(Φ_TᵀΦ_T) < 2 — the classical gradient-descent bound.
#: Measured: at κ̄ = S_c/2 the restricted estimate λ̂ ≈ 4.4 (S=512) / 5.0
#: (S=1024), and the iterate blows up exactly where τ·λ̂ crosses 2
#: (stable at 1.75, diverged at 2.005) — see tests/test_decode.py.
IHT_STABILITY_BOUND = 2.0


def restricted_spectral_estimate(phi: jnp.ndarray, k: int,
                                 iters: int = 20) -> jnp.ndarray:
    """λ̂ ≈ max λ(Φ_TᵀΦ_T) over k-sparse supports T — the quantity that
    decides fixed-step IHT stability (DESIGN.md §13).

    Hard-thresholded power iteration from a deterministic all-ones start:
    v ← η_k(ΦᵀΦ v)/‖·‖. The fixed-step update x ← η_κ(x + τΦᵀ(y − Φx))
    contracts on the iterate support only when τ·λ̂ < 2
    (``IHT_STABILITY_BOUND``); at the default decode budget κ̄ = S_c/2 the
    estimate is ≈4.4–5.0 for Gaussian Φ with the 1/S normalization, so the
    edge sits at τ ≈ 0.4–0.46 — consistent with the conservatively pinned
    τ = 0.25 and the silent divergence beyond it (CHANGES PR-2 note,
    benchmarks/decoders_bench.py). Traceable (scan + top_k), so it also
    runs under jit for the cond-based fallback."""
    d = phi.shape[1]
    s = min(k, d)

    def step(v, _):
        w = hard_threshold(jnp.einsum("sd,s->d", phi,
                                      jnp.einsum("sd,d->s", phi, v)), s)
        nrm = jnp.linalg.norm(w)
        return w / jnp.maximum(nrm, 1e-30), None

    v0 = jnp.full((d,), 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32)),
                  phi.dtype)
    v, _ = jax.lax.scan(step, v0, None, length=iters)
    pv = jnp.einsum("sd,d->s", phi, v)
    return jnp.sum(pv * pv) / jnp.maximum(jnp.sum(v * v), 1e-30)


def iht_step_stable(phi: jnp.ndarray, k: int, tau: float,
                    iters: int = 20) -> jnp.ndarray:
    """Traced bool: is the fixed step τ below the restricted stability
    edge τ·λ̂ < 2 (``IHT_STABILITY_BOUND``, DESIGN.md §13)?"""
    return (restricted_spectral_estimate(phi, k, iters) * tau
            < IHT_STABILITY_BOUND)


def iht(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 10,
        tau: float = 1.0, ht_fn=None, x0=None) -> jnp.ndarray:
    """Fixed-step IHT on real measurements (eq. 43). y: (..., S);
    phi: (S, D). Returns (..., D).

    tau is scaled by 1/||Φ||² proxy = 1 (Φ has unit spectral norm in
    expectation under the 1/S normalization). ``x0`` warm-starts the
    iterate (defaults to zeros — the cold start)."""
    ht = ht_fn or hard_threshold

    def step(x, _):
        resid = y - jnp.einsum("sd,...d->...s", phi, x)
        x = x + tau * jnp.einsum("sd,...s->...d", phi, resid)
        return ht(x, k), None

    if x0 is None:
        x0 = jnp.zeros(y.shape[:-1] + (phi.shape[1],), y.dtype)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def niht(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 10,
         ht_fn=None, x0=None) -> jnp.ndarray:
    """Normalized IHT (eq. 43 with an adaptive step).

    Per iteration the step μ = ||g_Λ||²/||Φ g_Λ||² is exact line search
    along the support-restricted gradient g_Λ (Λ = supp(x); the full
    gradient when the support is empty, i.e. the cold first step). Costs
    one extra projection per iteration over ``iht`` but needs no τ."""
    ht = ht_fn or hard_threshold

    def step(x, _):
        resid = y - jnp.einsum("sd,...d->...s", phi, x)
        g = jnp.einsum("sd,...s->...d", phi, resid)
        on_support = jnp.any(x != 0, axis=-1, keepdims=True)
        gs = jnp.where(on_support, g * (x != 0), g)
        num = jnp.sum(gs * gs, axis=-1, keepdims=True)
        pg = jnp.einsum("sd,...d->...s", phi, gs)
        den = jnp.sum(pg * pg, axis=-1, keepdims=True)
        mu = num / jnp.maximum(den, 1e-30)
        return ht(x + mu * g, k), None

    if x0 is None:
        x0 = jnp.zeros(y.shape[:-1] + (phi.shape[1],), y.dtype)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def biht_sign(y_sign: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int = 30,
              tau: float = 1.0, ht_fn=None, x0=None) -> jnp.ndarray:
    """Classic BIHT (sign-consistency subgradient, eq. 43 on sign
    measurements), unit-norm output. ``x0`` warm-starts the iterate
    (default: the thresholded back-projection η_κ(Φᵀy/S))."""
    S = phi.shape[0]
    ht = ht_fn or hard_threshold

    def step(x, _):
        resid = y_sign - sign_pm1(jnp.einsum("sd,...d->...s", phi, x))
        x = x + (tau / S) * jnp.einsum("sd,...s->...d", phi, resid)
        x = ht(x, k)
        return x, None

    if x0 is None:
        x0 = jnp.einsum("sd,...s->...d", phi, y_sign) / S
        x0 = ht(x0, k)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)
