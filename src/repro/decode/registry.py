"""Pluggable 1-bit CS decoder registry — one entry point for eq. 43.

Every paper figure and the production train step decode through
``decode(y, phi, k, cfg)`` (DESIGN.md §9); the decoder is a registry
lookup, so codec experiments (step rule, warm start, Pallas fusion) are a
config string away in both execution modes (DESIGN.md §2).

Built-in decoders:

  iht        fixed-step IHT on real measurements (eq. 43); routes through
             the fused-Pallas hot loop when ``cfg.use_kernels``
  niht       normalized (adaptive-step) IHT — exact line search per step
  biht       classic sign-consistency BIHT (paper §V choice)
  iht_warm   IHT seeded with round t−1's estimate (``x0``); cold start
             when no state is available
  iht_fused  the fused-Pallas loop unconditionally (benchmark pinning)

Warm-start protocol: ``decode`` forwards ``x0`` only to decoders
registered with ``warm=True`` — cold decoders stay bit-stable no matter
what state the caller carries. State itself lives with the caller
(``repro.fl.rounds``; reset on schedule change, DESIGN.md §9).

Sharding: ``y`` and the returned estimate are constrained chunk-sharded
over the mesh (``repro.dist.sharding.constrain``) — the chunk dimension is
embarrassingly parallel (DESIGN.md §4) and the constraint degrades to a
no-op off-mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

from repro.dist.sharding import constrain
from repro.decode.fused import fused_biht_packed, fused_iht
from repro.decode.iht import (IHT_STABILITY_BOUND, biht_sign,
                              hard_threshold, hard_threshold_bisect, iht,
                              iht_step_stable, niht,
                              restricted_spectral_estimate)


@dataclass(frozen=True)
class DecodeConfig:
    """Decoder selection + knobs, consumed by ``decode``.

    ``OBCSAAConfig.decode_cfg()`` derives one from the aggregation config;
    benchmarks construct them directly.

    ``ht`` selects the hard-threshold implementation for the EINSUM
    decoders only: "sort" (exact ``lax.top_k``, index tie-break) or
    "bisect" (SPMD-partitionable threshold search). Kernel paths
    (``use_kernels``/``iht_fused``) always threshold via the bisection
    kernel — identical selection except on exact magnitude ties, which
    are measure-zero for float gradients (kernels/topk_select.py)."""
    algorithm: str = "biht"
    iters: int = 30
    tau: float = 1.0
    use_kernels: bool = False     # fused-Pallas hot loop where supported
    ht: str = "sort"              # sort | bisect (SPMD-friendly threshold)
    ht_iters: int = 40            # bisect resolution budget (max·2^-iters)
    shard_axes: Tuple = ("model", None)   # chunk-dim mesh constraint
    # Packed 1-bit measurements (DESIGN.md §13): ``y`` arrives as uint32
    # words (32 signs each, kernels/sign.py codec). Only the sign-
    # consistency ``biht`` family decodes packed symbols — the iht family
    # consumes the real-valued post-MAC aggregate, which has no 1-bit form.
    packed: bool = False
    # Fixed-step stability guard (DESIGN.md §13): "off" | "raise" |
    # "fallback". Checks τ·λ̂ < 2 (λ̂ = restricted spectral estimate of Φ
    # at the decode sparsity) before dispatching the iht family; beyond
    # the edge the iterate silently diverges to NaN. "raise" errors
    # eagerly; "fallback" swaps in the adaptive-step NIHT (and is what
    # "raise" degrades to under jit, where a data-dependent raise is
    # impossible). "off" (default) keeps existing traces bitwise.
    validate: str = "off"


@dataclass(frozen=True)
class Decoder:
    """Registry entry: the decode fn + whether it consumes warm state."""
    fn: Callable
    warm: bool = False


_REGISTRY: Dict[str, Decoder] = {}


def register_decoder(name: str, *, warm: bool = False):
    """Register ``fn(y, phi, k, cfg, x0) -> xhat`` under ``name``."""
    def deco(fn):
        _REGISTRY[name] = Decoder(fn=fn, warm=warm)
        return fn
    return deco


def get_decoder(name: str) -> Decoder:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown decoder {name!r}; registered: "
                         f"{', '.join(list_decoders())}") from None


def list_decoders():
    return sorted(_REGISTRY)


def _ht_fn(cfg: DecodeConfig):
    if cfg.ht == "bisect":
        import functools
        return functools.partial(hard_threshold_bisect, iters=cfg.ht_iters)
    if cfg.ht == "sort":
        return hard_threshold
    raise ValueError(f"unknown hard-threshold {cfg.ht!r} (sort|bisect)")


_FIXED_STEP = ("iht", "iht_warm", "iht_fused")
_VALIDATE_MODES = ("off", "raise", "fallback")


def decode(y, phi, k: int, cfg: DecodeConfig, x0=None):
    """Decode the post-processed aggregate ŷ (eq. 13) back to the sparse
    gradient estimate (eq. 43). y: (n, S); phi: (S, D) -> (n, D). With
    ``cfg.packed``, y is instead the uint32 packed sign words (n, S//32).

    ``x0`` is the warm-start iterate (round t−1's raw estimate); it is
    forwarded only to warm-capable decoders.

    ``cfg.validate`` guards the fixed-step iht family against the silent
    τ-divergence (DESIGN.md §13): eagerly it raises (or falls back to
    NIHT) when τ·λ̂ ≥ 2; under jit both modes become a ``lax.cond``
    between the requested decoder and NIHT."""
    dec = get_decoder(cfg.algorithm)
    y = constrain(y, cfg.shard_axes)
    x0w = x0 if dec.warm else None
    if cfg.validate not in _VALIDATE_MODES:
        raise ValueError(f"unknown validate mode {cfg.validate!r}; one of "
                         f"{_VALIDATE_MODES} (DESIGN.md §13)")
    if cfg.validate != "off" and cfg.algorithm in _FIXED_STEP:
        import jax
        if isinstance(phi, jax.core.Tracer) or isinstance(y, jax.core.Tracer):
            niht_fn = _REGISTRY["niht"].fn
            # cfg/k are static — close over them; only arrays ride the cond
            x = jax.lax.cond(
                iht_step_stable(phi, k, cfg.tau),
                lambda yy, pp, xx: dec.fn(yy, pp, k, cfg, xx),
                lambda yy, pp, xx: niht_fn(yy, pp, k, cfg, None),
                y, phi, x0w)
            return constrain(x, cfg.shard_axes)
        lam = float(restricted_spectral_estimate(phi, k))
        if lam * cfg.tau >= IHT_STABILITY_BOUND:
            if cfg.validate == "raise":
                raise ValueError(
                    f"decode: fixed-step IHT is unstable at tau={cfg.tau}: "
                    f"tau·λ̂ = {cfg.tau * lam:.2f} ≥ {IHT_STABILITY_BOUND}, "
                    f"with λ̂ = {lam:.2f} the restricted spectral estimate "
                    f"of Φ at decode sparsity k={k} — the iterate diverges "
                    f"to NaN. Lower tau below "
                    f"{IHT_STABILITY_BOUND / lam:.3f}, use "
                    f"validate='fallback', or the adaptive-step 'niht' "
                    f"decoder (DESIGN.md §13).")
            dec = _REGISTRY["niht"]
            x0w = None
    x = dec.fn(y, phi, k, cfg, x0w)
    return constrain(x, cfg.shard_axes)


# --- built-ins --------------------------------------------------------------------

@register_decoder("iht")
def _iht(y, phi, k, cfg, x0):
    if cfg.use_kernels:
        return fused_iht(y, phi, k, cfg.iters, cfg.tau, x0=x0)
    return iht(y, phi, k, cfg.iters, cfg.tau, ht_fn=_ht_fn(cfg), x0=x0)


@register_decoder("iht_warm", warm=True)
def _iht_warm(y, phi, k, cfg, x0):
    return _iht(y, phi, k, cfg, x0)


@register_decoder("iht_fused", warm=True)
def _iht_fused(y, phi, k, cfg, x0):
    return fused_iht(y, phi, k, cfg.iters, cfg.tau, x0=x0)


@register_decoder("niht")
def _niht(y, phi, k, cfg, x0):
    return niht(y, phi, k, cfg.iters, ht_fn=_ht_fn(cfg), x0=x0)


@register_decoder("biht")
def _biht(y, phi, k, cfg, x0):
    if cfg.packed:
        if cfg.use_kernels:
            return fused_biht_packed(y, phi, k, cfg.iters, cfg.tau)
        from repro.kernels.sign import unpack_signs
        y = unpack_signs(y, phi.dtype)
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        return kops.biht(y, phi, k, cfg.iters, cfg.tau)
    return biht_sign(y, phi, k, cfg.iters, cfg.tau, ht_fn=_ht_fn(cfg),
                     x0=x0)
