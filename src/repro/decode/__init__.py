"""repro.decode — pluggable 1-bit CS decoder subsystem (eq. 43).

The PS-side reconstruction hot path, as a registry of interchangeable
decoders behind one entry point (``decode``), with the IHT inner iteration
fused through the Pallas kernels (``repro.kernels``) and chunk-sharded on
the mesh (``repro.dist``). See DESIGN.md §9.

Layering: this package imports ``repro.kernels`` and ``repro.dist`` only;
``repro.core`` consumes it (never the reverse at module scope), so the
decoders are usable standalone — benchmarks and tests drive them without
an aggregation config.
"""
from repro.decode.fused import fused_iht
from repro.decode.iht import (biht_sign, hard_threshold,
                              hard_threshold_bisect, iht, niht)
from repro.decode.registry import (DecodeConfig, Decoder, decode,
                                   get_decoder, list_decoders,
                                   register_decoder)

__all__ = [
    "DecodeConfig", "Decoder", "biht_sign", "decode", "fused_iht",
    "get_decoder", "hard_threshold", "hard_threshold_bisect", "iht",
    "list_decoders", "niht", "register_decoder",
]
