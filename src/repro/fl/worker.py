"""FL worker: local gradient computation + OBCSAA transmit side (eq. 3, 6-7, 10)."""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.obcsaa import OBCSAAConfig, compress_chunks
from repro.core.sparsify import flatten_pytree


def local_gradient(loss_fn: Callable, params, data) -> Tuple:
    """Full-batch GD gradient on this worker's local dataset (eq. 3)."""
    return jax.grad(lambda p: loss_fn(p, data))(params)


def stacked_local_gradients(loss_fn: Callable, params, stacked_data):
    """vmap over U workers' datasets. stacked_data leaves: (U, ...).

    Returns stacked flat gradients (U, D)."""
    def one(data):
        g = local_gradient(loss_fn, params, data)
        flat, _ = flatten_pytree(g)
        return flat

    return jax.vmap(one)(stacked_data)


def transmit(cfg: OBCSAAConfig, flat_grad: jnp.ndarray, *, k_weight, beta_i,
             b_t, phi=None):
    """Worker-side pipeline: sparse_κ -> Φ -> sign -> power scale (eq. 10).

    Channel inversion makes the effective transmitted weight K_i β_i b_t
    (the h_i cancels at the receiver, eq. 12)."""
    pad = (-flat_grad.shape[0]) % cfg.chunk
    gpad = jnp.pad(flat_grad, (0, pad))
    signs, mags = compress_chunks(cfg, gpad, phi)
    w = (k_weight * beta_i * b_t).astype(signs.dtype)
    return signs * w, mags
