from repro.fl.rounds import (FederatedTrainer, FLConfig, RoundLog,
                             SchedLog)
from repro.fl.server import receive_and_reconstruct, schedule_round
from repro.fl.worker import local_gradient, stacked_local_gradients, transmit

__all__ = ["FederatedTrainer", "FLConfig", "RoundLog", "SchedLog",
           "receive_and_reconstruct", "schedule_round", "local_gradient",
           "stacked_local_gradients", "transmit"]
