"""FL round orchestration — thin host wrapper over ``repro.engine``
(DESIGN.md §11).

Each round t:
  1. PS draws this round's block-fading channels h_{i,t} (known CSI,
     Rayleigh via core/channel.py's Gauss-Markov fade state).
  2. PS solves P2 (scheduling method: all | enum | admm | greedy |
     admm_batched | greedy_batched, DESIGN.md §10) -> β_t, b_t.
  3. Scheduled workers compute local full-batch gradients (eq. 3), compress
     (eq. 6-7), power-scale (eq. 10) and transmit simultaneously.
  4. The MAC superimposes; PS adds AWGN, post-processes (eq. 13), decodes
     (eq. 43, via the repro.decode registry — warm-start state rides the
     engine carry, DESIGN.md §9) and broadcasts ĝ_t; everyone updates w
     (eq. 14).

Two execution modes over ONE round body (``repro.engine.core``):

- ``scan``: the device-resident engine — rounds advance as jitted
  ``lax.scan`` chunks cut at the eval cadence, state donated between
  chunks; requires a jittable scheduler (``ENGINE_SCHEDULERS``).
- ``host``: the per-round reference loop — fade draw, registry scheduling
  (this is where the non-jittable ``enum``/NumPy oracles run), then the
  same jitted round body. The parity oracle: scan ≡ host bitwise at
  float32 (tests/test_engine.py).

Aggregators:
  perfect  — error-free weighted mean (paper's "perfect aggregation" bench)
  topk_aa  — top-κ sparsified analog aggregation, no CS/quantization
             (the [21,22]-style baseline the paper compares against)
  obcsaa   — the paper's full 1-bit CS pipeline
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import ENGINE_SCHEDULERS, EngineRun, FLConfig  # noqa: F401
from repro.engine.runner import chunk_spans
from repro.fl.server import schedule_round
from repro.optim.optimizers import Optimizer


@dataclass
class RoundLog:
    """Eval-cadence metrics (loss/accuracy stream)."""
    round: int
    loss: float
    accuracy: float
    n_scheduled: int
    b_t: float


@dataclass
class SchedLog:
    """Dense per-round scheduling + theory stats — emitted EVERY round
    from the scan carry (no eval-gated holes; DESIGN.md §11/§12).
    ``rt_bound`` is the predicted Theorem-1 R_t at the round's operating
    point (repro.theory; NaN for non-obcsaa aggregators — eq. 19 models
    the 1-bit CS pipeline); ``agg_err`` is the measured ‖ĝ−ḡ‖² probe,
    NaN unless ``FLConfig.probe_agg_error`` is on."""
    round: int
    n_scheduled: int
    b_t: float
    rt_bound: float = float("nan")
    agg_err: float = float("nan")


class FederatedTrainer:
    """Drives FL rounds for any (loss_fn, params) pair + stacked worker
    data; delegates the round body to ``repro.engine`` and keeps only
    orchestration + metrics streaming on the host."""

    def __init__(self, cfg: FLConfig, loss_fn: Callable, params,
                 worker_data, k_weights: np.ndarray,
                 eval_fn: Optional[Callable] = None,
                 optimizer: Optional[Optimizer] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.worker_data = worker_data
        self.k_weights = np.asarray(k_weights, np.float64)
        self.eval_fn = eval_fn
        self._mode = cfg.resolved_mode()
        self._engine = EngineRun(cfg, loss_fn, params, worker_data,
                                 self.k_weights, eval_fn=eval_fn,
                                 optimizer=optimizer)
        self.opt = self._engine.opt
        self.D = self._engine.fns.D
        self._state, self._arm = self._engine.init()
        self.logs: List[RoundLog] = []
        self.sched_logs: List[SchedLog] = []
        # host-path programs: the SAME engine round functions, one round
        # per dispatch (the scan-parity oracle)
        fns = self._engine.fns
        self._fade_jit = jax.jit(fns.fade_step)
        self._round_jit = jax.jit(fns.round_given_schedule)
        self._sched_jit = (jax.jit(fns.schedule)
                           if cfg.engine_capable()
                           and cfg.aggregator != "perfect" else None)

    # -- state passthrough ------------------------------------------------

    @property
    def params(self):
        return self._state.params

    @property
    def opt_state(self):
        return self._state.opt_state

    @property
    def sched_trajectory(self) -> Dict[str, np.ndarray]:
        """Dense (rounds,) scheduling + theory trajectories."""
        return {
            "round": np.asarray([s.round for s in self.sched_logs]),
            "n_scheduled": np.asarray([s.n_scheduled
                                       for s in self.sched_logs]),
            "b_t": np.asarray([s.b_t for s in self.sched_logs]),
            "rt_bound": np.asarray([s.rt_bound for s in self.sched_logs]),
            "agg_err": np.asarray([s.agg_err for s in self.sched_logs]),
        }

    # -- host reference path ----------------------------------------------

    def run_round(self, t: int) -> Dict:
        """One host-orchestrated round: device fade draw, scheduling via
        the registry (NumPy oracles incl. ``enum`` run here), then the
        engine's jitted round body."""
        cfg = self.cfg
        arm = self._arm
        U = len(self.k_weights)
        k_t = jax.random.fold_in(arm.key, t)
        h, fade = self._fade_jit(self._state.fade,
                                 jax.random.fold_in(k_t, 0))
        duals = None
        if cfg.aggregator == "perfect":
            beta = jnp.ones((U,), jnp.float32)
            b_t = jnp.float32(1.0)
        elif self._sched_jit is not None:
            beta, b_t, duals = self._sched_jit(h, self._engine.k_weights,
                                               arm.noise_var, arm.p_max,
                                               self._state.sched_duals)
        else:
            beta_np, bt = schedule_round(
                cfg.scheduler, np.asarray(h, np.float64), self.k_weights,
                cfg.obcsaa, cfg.const, self.D, cfg.sched_cfg)
            beta = jnp.asarray(beta_np, jnp.float32)
            b_t = jnp.float32(bt)
        self._state, stats = self._round_jit(
            self._state, arm, self.worker_data, self._engine.k_weights,
            jnp.int32(t), h, fade, beta, b_t, duals)
        self.sched_logs.append(SchedLog(
            t, int(stats.n_scheduled), float(stats.b_t),
            float(np.asarray(stats.budget.rt()))
            if stats.budget is not None else float("nan"),
            float(stats.agg_err) if stats.agg_err is not None
            else float("nan")))
        return {"beta": np.asarray(beta), "b_t": float(b_t),
                "h": np.asarray(h)}

    # -- scan engine path -------------------------------------------------

    def _run_scan(self, rounds: int, verbose: bool):
        cfg = self.cfg
        ee = cfg.eval_every if self.eval_fn else None
        for t0, n in chunk_spans(rounds, ee):
            self._state, stats = self._engine.run_chunk(self._state,
                                                        self._arm, t0, n)
            ns = np.asarray(stats.n_scheduled)
            bt = np.asarray(stats.b_t)
            rt = (np.asarray(stats.budget.rt())
                  if stats.budget is not None else np.full(n, np.nan))
            err = (np.asarray(stats.agg_err) if stats.agg_err is not None
                   else np.full(n, np.nan))
            self.sched_logs.extend(
                SchedLog(t0 + i, int(ns[i]), float(bt[i]), float(rt[i]),
                         float(err[i]))
                for i in range(n))
            if self.eval_fn:
                t = t0 + n - 1
                loss, acc = self.eval_fn(self.params)
                self.logs.append(RoundLog(t, float(loss), float(acc),
                                          int(ns[-1]), float(bt[-1])))
                if verbose:
                    print(f"round {t:4d} loss={float(loss):.4f} "
                          f"acc={float(acc):.4f} "
                          f"sched={int(ns[-1])}/{len(self.k_weights)}")

    def run(self, rounds: Optional[int] = None, verbose: bool = False):
        rounds = rounds or self.cfg.rounds
        if self._mode == "scan":
            self._run_scan(rounds, verbose)
            return self.logs
        for t in range(rounds):
            info = self.run_round(t)
            if self.eval_fn and (t % self.cfg.eval_every == 0
                                 or t == rounds - 1):
                loss, acc = self.eval_fn(self.params)
                self.logs.append(RoundLog(t, float(loss), float(acc),
                                          int(info["beta"].sum()),
                                          float(info["b_t"])))
                if verbose:
                    print(f"round {t:4d} loss={float(loss):.4f} "
                          f"acc={float(acc):.4f} "
                          f"sched={int(info['beta'].sum())}/{len(info['h'])}")
        return self.logs
