"""FL round orchestration: the paper's §V experiment engine.

Each round t:
  1. PS draws this round's block-fading channels h_{i,t} (known CSI).
  2. PS solves P2 via the repro.sched registry (scheduling method:
     all | enum | admm | greedy | admm_batched | greedy_batched,
     DESIGN.md §10) -> β_t, b_t.
  3. Scheduled workers compute local full-batch gradients (eq. 3), compress
     (eq. 6-7), power-scale (eq. 10) and transmit simultaneously.
  4. The MAC superimposes; PS adds AWGN, post-processes (eq. 13), decodes
     (eq. 43, via the repro.decode registry — warm-start state is carried
     here across rounds, DESIGN.md §9) and broadcasts ĝ_t; everyone
     updates w (eq. 14).

Aggregators:
  perfect  — error-free weighted mean (paper's "perfect aggregation" bench)
  topk_aa  — top-κ sparsified analog aggregation, no CS/quantization
             (the [21,22]-style baseline the paper compares against)
  obcsaa   — the paper's full 1-bit CS pipeline
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as chan
from repro.core.error_floor import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig, simulate_round
from repro.core.sparsify import flatten_pytree, topk_sparsify
from repro.fl.server import schedule_round
from repro.fl.worker import stacked_local_gradients
from repro.optim.optimizers import Optimizer, sgd


@dataclass
class FLConfig:
    aggregator: str = "obcsaa"       # perfect | topk_aa | obcsaa
    # P2 solver, dispatched through the repro.sched registry (DESIGN.md
    # §10): all | enum | admm | greedy | admm_batched | greedy_batched
    scheduler: str = "all"
    learning_rate: float = 0.1       # paper §V
    rounds: int = 300
    eval_every: int = 10
    seed: int = 0
    obcsaa: OBCSAAConfig = field(default_factory=OBCSAAConfig)
    const: AnalysisConstants = field(default_factory=AnalysisConstants)
    # topk_aa baseline: same κ budget as obcsaa over the FULL vector
    topk_dense: int = 1000
    # Beyond-paper: per-worker error feedback (Stich et al., paper ref [37]):
    # each worker keeps the residual of its top-κ sparsification and adds it
    # to the next round's gradient before compression.
    error_feedback: bool = False


@dataclass
class RoundLog:
    round: int
    loss: float
    accuracy: float
    n_scheduled: int
    b_t: float


def _perfect_aggregate(grads_flat, k_weights, beta):
    w = (k_weights * beta)[:, None]
    return jnp.sum(grads_flat * w, axis=0) / jnp.maximum(
        jnp.sum(k_weights * beta), 1e-12)


def _topk_aa_aggregate(grads_flat, k_weights, beta, b_t, kappa, noise_var,
                       key):
    """Sparsified analog aggregation (no CS, no 1-bit): workers transmit
    their top-κ gradients directly; AWGN at the PS."""
    sp, _ = topk_sparsify(grads_flat, kappa)
    w = (k_weights * beta * b_t)[:, None]
    y = jnp.sum(sp * w, axis=0)
    y = y + chan.draw_noise(key, y.shape, noise_var)
    return y / jnp.maximum(jnp.sum(k_weights * beta) * b_t, 1e-12)


class FederatedTrainer:
    """Drives FL rounds for any (loss_fn, params) pair + stacked worker data."""

    def __init__(self, cfg: FLConfig, loss_fn: Callable, params,
                 worker_data, k_weights: np.ndarray,
                 eval_fn: Optional[Callable] = None,
                 optimizer: Optional[Optimizer] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.params = params
        self.worker_data = worker_data
        self.k_weights = np.asarray(k_weights, np.float64)
        self.eval_fn = eval_fn
        self.opt = optimizer or sgd()
        self.opt_state = self.opt.init(params)
        flat, self._unflatten = flatten_pytree(params)
        self.D = int(flat.shape[0])
        self._rng = np.random.default_rng(cfg.seed)
        self.logs: List[RoundLog] = []
        self._grad_fn = jax.jit(functools.partial(stacked_local_gradients,
                                                  loss_fn))
        self._agg_fn = jax.jit(self._aggregate)
        U = len(self.k_weights)
        # Warm-start decode state (DESIGN.md §9): round t's decoder is
        # seeded with round t−1's RAW estimate; zeros = cold start. Reset
        # whenever the schedule changes (the aggregate's support mixture
        # shifts, so stale state would bias the decode).
        ob = cfg.obcsaa
        self._n_chunks = -(-self.D // ob.chunk)
        self._decode_x0 = (jnp.zeros((self._n_chunks, ob.chunk))
                           if (cfg.aggregator == "obcsaa" and ob.warm_start)
                           else None)
        self._prev_beta = None
        self._residual = jnp.zeros((U, self.D)) if cfg.error_feedback \
            else None
        if cfg.error_feedback:
            from repro.core.sparsify import topk_sparsify_chunked
            ob = cfg.obcsaa
            n_chunks = -(-self.D // ob.chunk)
            pad = n_chunks * ob.chunk - self.D

            @jax.jit
            def ef_split(grads, residual):
                corrected = grads + residual
                gp = jnp.pad(corrected, ((0, 0), (0, pad)))
                sp, _ = jax.vmap(lambda g: topk_sparsify_chunked(
                    g, ob.topk, ob.chunk))(gp)
                sp = sp[:, :self.D]
                return corrected, corrected - sp

            self._ef_split = ef_split

    def _aggregate(self, grads_flat, k_weights, beta, b_t, h, key,
                   decode_x0=None):
        cfg = self.cfg
        if cfg.aggregator == "perfect":
            return _perfect_aggregate(grads_flat, k_weights, beta), None
        if cfg.aggregator == "topk_aa":
            return _topk_aa_aggregate(grads_flat, k_weights, beta, b_t,
                                      cfg.topk_dense, cfg.obcsaa.noise_var,
                                      key), None
        ghat, diag = simulate_round(cfg.obcsaa, grads_flat, k_weights, beta,
                                    b_t, h, key, decode_x0=decode_x0)
        # only thread the raw estimate out of the jit when warm-start state
        # is actually carried — otherwise it is a dead D-sized output
        return ghat, (diag["decode_xhat"] if cfg.obcsaa.warm_start else None)

    def run_round(self, t: int) -> Dict:
        cfg = self.cfg
        U = len(self.k_weights)
        h = np.abs(self._rng.normal(size=U))
        h = np.maximum(h, chan.H_MIN)
        if cfg.aggregator == "perfect":
            beta, b_t = np.ones(U), 1.0
        else:
            beta, b_t = schedule_round(cfg.scheduler, h, self.k_weights,
                                       cfg.obcsaa, cfg.const, self.D)
        grads = self._grad_fn(self.params, self.worker_data)     # (U, D)
        if self._residual is not None:
            grads, self._residual = self._ef_split(grads, self._residual)
        if (self._decode_x0 is not None and self._prev_beta is not None
                and not np.array_equal(beta, self._prev_beta)):
            # schedule change -> reset warm-start state (DESIGN.md §9)
            self._decode_x0 = jnp.zeros_like(self._decode_x0)
        key = jax.random.PRNGKey(cfg.seed * 100003 + t)
        ghat, xraw = self._agg_fn(grads,
                                  jnp.asarray(self.k_weights, jnp.float32),
                                  jnp.asarray(beta, jnp.float32),
                                  jnp.asarray(b_t, jnp.float32),
                                  jnp.asarray(h, jnp.float32), key,
                                  self._decode_x0)
        if self._decode_x0 is not None:
            self._decode_x0 = xraw
        self._prev_beta = np.asarray(beta).copy()
        g_tree = self._unflatten(ghat[:self.D])
        self.params, self.opt_state = self.opt.update(
            g_tree, self.opt_state, self.params, cfg.learning_rate)
        return {"beta": beta, "b_t": b_t, "h": h}

    def run(self, rounds: Optional[int] = None, verbose: bool = False):
        rounds = rounds or self.cfg.rounds
        for t in range(rounds):
            info = self.run_round(t)
            if self.eval_fn and (t % self.cfg.eval_every == 0
                                 or t == rounds - 1):
                loss, acc = self.eval_fn(self.params)
                self.logs.append(RoundLog(t, float(loss), float(acc),
                                          int(info["beta"].sum()),
                                          float(info["b_t"])))
                if verbose:
                    print(f"round {t:4d} loss={float(loss):.4f} "
                          f"acc={float(acc):.4f} "
                          f"sched={int(info['beta'].sum())}/{len(info['h'])}")
        return self.logs
