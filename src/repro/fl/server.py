"""FL parameter server: scheduling (P2), post-processing, reconstruction,
broadcast (paper eq. 13-14, §IV)."""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.theory.bounds import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig, reconstruct_chunks
from repro.sched import Problem, SchedConfig, schedule


def schedule_round(method: str, h: np.ndarray, k_weights: np.ndarray,
                   cfg: OBCSAAConfig, const: AnalysisConstants, D: int,
                   sched_cfg: Optional[SchedConfig] = None
                   ) -> Tuple[np.ndarray, float]:
    """Solve P2 for this round's channels via the ``repro.sched`` registry
    (DESIGN.md §10; method: all | enum | admm | greedy | admm_batched |
    greedy_batched | any registered name). Returns (β, b_t)."""
    prob = Problem(h=h, k_weights=k_weights, p_max=cfg.p_max,
                   noise_var=cfg.noise_var, D=D, S=cfg.measure,
                   kappa=cfg.topk, const=const)
    beta, bt, _ = schedule(prob, method, sched_cfg)
    return beta, bt


def receive_and_reconstruct(cfg: OBCSAAConfig, y_sum: jnp.ndarray,
                            mags_sum: jnp.ndarray, *, ksum_beta, b_t, noise,
                            D: int, phi=None) -> jnp.ndarray:
    """PS receive side: add AWGN, post-process (eq. 13), decode (eq. 43)."""
    denom = jnp.maximum(ksum_beta * b_t, 1e-12)
    y = (y_sum + noise) / denom
    mbar = mags_sum / jnp.maximum(ksum_beta, 1e-12)
    ghat = reconstruct_chunks(cfg, y, mbar if cfg.magnitude_tracking else None,
                              phi)
    return ghat[:D]
