"""FL parameter server: scheduling (P2), post-processing, reconstruction,
broadcast (paper eq. 13-14, §IV)."""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.error_floor import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig, reconstruct_chunks
from repro.core.scheduling import (Problem, admm_solve, enumerate_solve,
                                   greedy_solve, optimal_bt)


def schedule_round(method: str, h: np.ndarray, k_weights: np.ndarray,
                   cfg: OBCSAAConfig, const: AnalysisConstants, D: int
                   ) -> Tuple[np.ndarray, float]:
    """Solve P2 for this round's channels. Returns (β, b_t)."""
    prob = Problem(h=h, k_weights=k_weights, p_max=cfg.p_max,
                   noise_var=cfg.noise_var, D=D, S=cfg.measure,
                   kappa=cfg.topk, const=const)
    if method == "all":
        beta = np.ones(len(h))
        return beta, optimal_bt(prob, beta)
    if method == "enum":
        beta, bt, _ = enumerate_solve(prob)
    elif method == "admm":
        beta, bt, _ = admm_solve(prob)
    elif method == "greedy":
        beta, bt, _ = greedy_solve(prob)
    else:
        raise ValueError(f"unknown scheduling method {method!r}")
    return beta, bt


def receive_and_reconstruct(cfg: OBCSAAConfig, y_sum: jnp.ndarray,
                            mags_sum: jnp.ndarray, *, ksum_beta, b_t, noise,
                            D: int, phi=None) -> jnp.ndarray:
    """PS receive side: add AWGN, post-process (eq. 13), decode (eq. 43)."""
    denom = jnp.maximum(ksum_beta * b_t, 1e-12)
    y = (y_sum + noise) / denom
    mbar = mags_sum / jnp.maximum(ksum_beta, 1e-12)
    ghat = reconstruct_chunks(cfg, y, mbar if cfg.magnitude_tracking else None,
                              phi)
    return ghat[:D]
