from repro.serve.cli import main

raise SystemExit(main())
