"""Scheduling-service CLI: ``python -m repro.serve`` (DESIGN.md §15).

Runs the continuous fleet-scheduling loop over a synthetic Gauss-Markov
fleet and prints per-tick telemetry plus the SLO summary — the same
loop benchmarks/serve_bench.py times at 10k–1M cells. Also reachable as
``python -m repro.launch.train --serve ...``.
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax

from repro.sched.scenario import ScenarioConfig
from repro.serve.service import init_service, run_ticks, slo_summary
from repro.serve.state import SERVE_SCHEDULERS, ServeConfig


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.serve",
        description="continuous fleet-scheduling service (DESIGN.md §15)")
    p.add_argument("--cells", type=int, default=1024,
                   help="fleet size B (cells)")
    p.add_argument("--workers", type=int, default=16,
                   help="workers per cell U")
    p.add_argument("--ticks", type=int, default=20,
                   help="service ticks to run")
    p.add_argument("--threshold", type=float, default=0.05,
                   help="staleness threshold (relative channel movement)")
    p.add_argument("--scheduler", choices=SERVE_SCHEDULERS,
                   default="admm_batched")
    p.add_argument("--model", choices=("gauss_markov", "jakes", "iid"),
                   default="gauss_markov", help="fade model")
    p.add_argument("--corr", type=float, default=0.99,
                   help="Gauss-Markov fade correlation rho")
    p.add_argument("--update-frac", type=float, default=1.0,
                   help="fraction of cells reporting CSI per tick")
    p.add_argument("--no-warm-duals", action="store_true",
                   help="disable ADMM dual warm-starting")
    p.add_argument("--seed", type=int, default=0)
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    cfg = ServeConfig(
        scenario=ScenarioConfig(cells=args.cells, workers=args.workers,
                                model=args.model, corr=args.corr),
        scheduler=args.scheduler, stale_threshold=args.threshold,
        warm_duals=not args.no_warm_duals, update_frac=args.update_frac)
    state = init_service(cfg, jax.random.PRNGKey(args.seed))
    print(f"serve: {args.cells} cells x {args.workers} workers, "
          f"{args.scheduler}, threshold={args.threshold}, "
          f"update_frac={args.update_frac}")
    state, stats, lat = run_ticks(cfg, state, args.ticks, timed=True)
    for s in stats:
        print(f"  tick {s.tick:4d}: reported={s.n_reported} "
              f"dirty={s.n_dirty} solved={s.n_solved} "
              f"hit_rate={s.hit_rate:.3f}")
    slo = slo_summary(stats, lat, args.cells)
    print(f"SLO: p50={slo['p50_ms']:.2f}ms p99={slo['p99_ms']:.2f}ms "
          f"hit_rate={slo['hit_rate']:.3f} "
          f"solved/s={slo['solved_per_s']:.0f} "
          f"served/s={slo['served_per_s']:.0f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
