"""The continuous scheduling service loop (DESIGN.md §15).

One tick = ingestion → dirty set → compaction → solve → cache:

1. **Ingest.** Advance the fleet's Gauss-Markov fade state one round
   (``sched/scenario.py``'s ``step_fades`` — the same executable the
   trajectory generator chains) and deliver CSI reports for a
   ``update_frac`` subset of cells; ``ingest`` accepts out-of-band
   pushes for externally measured channels.
2. **Dirty set.** A cell is dirty when its worst-worker relative channel
   movement since its last solve exceeds ``stale_threshold``. Cells
   without a new report moved exactly 0 and stay cached, so at
   threshold 0 the cache serves precisely the schedules a fresh solve
   of the current channels would produce (the ``fresh_solve`` parity
   flag benchmarks/serve_bench.py gates in CI).
3. **Compact + solve.** Dirty cells are padded into the shared pow2
   buckets (``sched/compaction.py`` — bounded jit entries, collision-safe
   scatters) and dispatched to the fleet solver; ADMM solves are seeded
   with each cell's previous exit multipliers (β bitwise-unchanged).
4. **Cache.** Results scatter back into the served-schedule arrays next
   to the channels they were solved for; the exit duals ride along for
   the next warm start.

Everything here is host-orchestrated around the device-resident batched
solvers — the same host-compaction discipline ``admm_solve_batched``
itself uses between convergence chunks (DESIGN.md §10).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sched.admm import AdmmDuals, admm_solve_batched
from repro.sched.compaction import pad_to_bucket, take
from repro.sched.greedy import greedy_solve_batched
from repro.sched.problem import BatchedProblem
from repro.sched.scenario import (init_fades, large_scale_gain, magnitudes,
                                  step_fades)
from repro.serve.state import ServeConfig, ServeState, TickStats

_REPORT_FOLD = 0x5EED   # fold_in tag separating the CSI-report stream
                        # from the fade-innovation stream (fold_in(key, t))


def _problem(cfg: ServeConfig, h: jnp.ndarray) -> BatchedProblem:
    return BatchedProblem.from_arrays(
        h, cfg.k_weights, cfg.p_max, cfg.noise_var, D=cfg.D, S=cfg.S,
        kappa=cfg.kappa, const=cfg.const)


def init_service(cfg: ServeConfig, key) -> ServeState:
    """Fresh service state: stationary fades, static large-scale gains,
    and an empty cache — ``h_solved`` starts at zero, so every cell is
    dirty on the first tick regardless of the report mask (after tick 0
    the whole fleet holds a served schedule)."""
    kf, kg = jax.random.split(key)
    fades = init_fades(cfg.scenario, kf)
    gain = large_scale_gain(cfg.scenario, kg)
    cells, U = gain.shape
    z = jnp.zeros((cells, U), jnp.float32)
    return ServeState(
        fades=fades, gain=gain,
        h_seen=magnitudes(fades, gain, cfg.scenario.h_min),
        h_solved=z, beta=z, b_t=jnp.zeros((cells,), jnp.float32),
        rt=jnp.zeros((cells,), jnp.float32),
        duals=AdmmDuals.zeros((cells, U)) if cfg.warm else None,
        tick=0)


def movement(cfg: ServeConfig, state: ServeState) -> np.ndarray:
    """(cells,) worst-worker relative channel movement since each cell's
    last solve: max_i |h_seen − h_solved| / max(h_solved, h_min). Exactly
    0 for cells whose CSI has not changed — the staleness metric the
    threshold cuts."""
    rel = jnp.abs(state.h_seen - state.h_solved) / jnp.maximum(
        state.h_solved, cfg.scenario.h_min)
    return np.asarray(jnp.max(rel, axis=-1))


def ingest(state: ServeState, cell_ids: Sequence[int],
           h: jnp.ndarray) -> ServeState:
    """Out-of-band CSI push: record externally measured channel
    magnitudes for ``cell_ids``. The cells become dirty on the next tick
    through the ordinary movement metric — no separate dirty bit."""
    ids = jnp.asarray(np.asarray(cell_ids, np.int32))
    h = jnp.asarray(h, jnp.float32)
    return state._replace(h_seen=state.h_seen.at[ids].set(h))


def _solve_dirty(cfg: ServeConfig, state: ServeState,
                 dirty: np.ndarray) -> Tuple[ServeState, int, float]:
    """Compact the dirty cells into a pow2 bucket, solve, scatter back.
    Returns (state', bucket size, mean ADMM iters). Pad lanes duplicate
    the first dirty cell; the solvers are deterministic, so every
    duplicate writes the identical value (collision-safe scatter,
    sched/compaction.py)."""
    pad, _ = pad_to_bucket(dirty, cfg.min_bucket)
    pad_j = jnp.asarray(pad)
    h_sub = state.h_seen[pad_j]
    prob = _problem(cfg, h_sub)
    mean_iters = float("nan")
    duals = state.duals
    if cfg.scheduler == "greedy_batched":
        beta_s, bt_s, rt_s = greedy_solve_batched(prob, cfg.sched_cfg)
    else:
        duals_in = take(duals, pad_j) if cfg.warm else None
        beta_s, bt_s, rt_s, info = admm_solve_batched(
            prob, cfg.sched_cfg, duals=duals_in, return_duals=True)
        mean_iters = float(info.iters.mean())
        if cfg.warm:
            duals = AdmmDuals(*(leaf.at[pad_j].set(new) for leaf, new
                                in zip(duals, info.duals)))
    state = state._replace(
        h_solved=state.h_solved.at[pad_j].set(h_sub),
        beta=state.beta.at[pad_j].set(beta_s),
        b_t=state.b_t.at[pad_j].set(bt_s),
        rt=state.rt.at[pad_j].set(rt_s),
        duals=duals)
    return state, len(pad), mean_iters


def tick(cfg: ServeConfig, state: ServeState
         ) -> Tuple[ServeState, TickStats]:
    """One service tick: fade step → CSI reports → dirty set → bucketed
    solve → cache update. Dirty-set selection runs on the host (the same
    host-driven compaction discipline as the ADMM convergence loop)."""
    cells = state.gain.shape[0]
    fades = step_fades(cfg.scenario, state.fades)
    h_now = magnitudes(fades, state.gain, cfg.scenario.h_min)
    if cfg.update_frac >= 1.0:
        n_reported, h_seen = cells, h_now
    else:
        kr = jax.random.fold_in(
            jax.random.fold_in(state.fades.key, _REPORT_FOLD), state.tick)
        report = jax.random.uniform(kr, (cells,)) < cfg.update_frac
        n_reported = int(jnp.sum(report))
        h_seen = jnp.where(report[:, None], h_now, state.h_seen)
    state = state._replace(fades=fades, h_seen=h_seen)

    dirty = np.flatnonzero(movement(cfg, state) > cfg.stale_threshold)
    n_solved, mean_iters = 0, float("nan")
    if dirty.size:
        state, n_solved, mean_iters = _solve_dirty(cfg, state, dirty)
    stats = TickStats(tick=state.tick, n_reported=n_reported,
                      n_dirty=int(dirty.size), n_solved=n_solved,
                      hit_rate=1.0 - dirty.size / cells,
                      mean_iters=mean_iters)
    return state._replace(tick=state.tick + 1), stats


def fresh_solve(cfg: ServeConfig, state: ServeState
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cold full-fleet solve of the current ``h_seen`` — the oracle the
    cache is checked against: at ``stale_threshold=0`` the served
    (β, b_t, R_t) must match this bitwise (both solvers are per-lane
    bitwise-invariant to batch composition, so bucketed incremental
    solves and this one-shot solve agree exactly)."""
    prob = _problem(cfg, state.h_seen)
    if cfg.scheduler == "greedy_batched":
        return greedy_solve_batched(prob, cfg.sched_cfg)
    beta, b_t, rt = admm_solve_batched(prob, cfg.sched_cfg)
    return beta, b_t, rt


def run_ticks(cfg: ServeConfig, state: ServeState, n: int,
              timed: bool = False
              ) -> Tuple[ServeState, List[TickStats], List[float]]:
    """Drive ``n`` ticks; with ``timed`` each tick is wall-clocked after
    a device sync (the serve-bench latency samples)."""
    stats: List[TickStats] = []
    lat: List[float] = []
    for _ in range(n):
        t0 = time.perf_counter()
        state, ts = tick(cfg, state)
        if timed:
            jax.block_until_ready(state.beta)
            lat.append(time.perf_counter() - t0)
        stats.append(ts)
    return state, stats, lat


def slo_summary(stats: Sequence[TickStats], lat: Sequence[float],
                cells: int) -> dict:
    """SLO aggregates for a timed run: p50/p99 tick latency, cache-hit
    rate, and throughput both as schedules actually solved per second
    and as cells served per second (solved + cache hits)."""
    lat = np.asarray(lat, np.float64)
    total = lat.sum()
    solved = sum(s.n_dirty for s in stats)
    out = {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
        "hit_rate": float(np.mean([s.hit_rate for s in stats])),
        "solved_per_s": float(solved / total) if total else float("nan"),
        "served_per_s": float(len(stats) * cells / total)
        if total else float("nan"),
    }
    return out
