"""repro.serve — continuous fleet-scheduling service (DESIGN.md §15).

The deployment-facing layer over ``repro.sched``: a service loop that
ingests streaming per-cell channel state (``sched/scenario.py``'s
incremental ``step_fades``), keeps a schedule cache keyed on channel
movement, re-solves only the dirty set — compacted into the shared pow2
buckets (``sched/compaction.py``) and dispatched to the batched P2
solvers with dual-warm-started ADMM — and serves (β, b_t, R_t) for the
whole fleet every tick. Two deterministic invariants pin it (gated in CI
by benchmarks/serve_bench.py): at ``stale_threshold=0`` the cache equals
a fresh full-fleet solve bitwise, and dual warm-starting never changes
β.

Layering: imports ``repro.sched`` (and transitively ``repro.theory``)
only; ``repro.launch`` and the benchmarks consume it.
"""
from repro.serve.service import (fresh_solve, ingest, init_service,
                                 movement, run_ticks, slo_summary, tick)
from repro.serve.state import (SERVE_SCHEDULERS, ServeConfig, ServeState,
                               TickStats)

__all__ = [
    "SERVE_SCHEDULERS", "ServeConfig", "ServeState", "TickStats",
    "fresh_solve", "ingest", "init_service", "movement", "run_ticks",
    "slo_summary", "tick",
]
