"""Serve-loop configuration and state pytrees (DESIGN.md §15).

``ServeConfig`` is the static side of the service — fleet geometry and
fade model (a ``ScenarioConfig``), the solver choice, the P2 problem
constants, and the caching policy (staleness threshold, CSI report
fraction, warm-start switch). ``ServeState`` is everything that evolves
tick to tick: the incremental fade process, the newest channel estimates
next to the channels each cached schedule was solved for (their gap IS
the staleness metric), the served schedules, and the ADMM exit
multipliers that warm-start each cell's next solve.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.sched.compaction import MIN_BUCKET
from repro.sched.config import SchedConfig
from repro.sched.scenario import FadeState, ScenarioConfig
from repro.theory.bounds import AnalysisConstants

# Solvers the serve loop can dispatch a dirty bucket to (both fleet-
# batched, repro.sched registry names; DESIGN.md §10)
SERVE_SCHEDULERS = ("admm_batched", "greedy_batched")


@dataclass(frozen=True)
class ServeConfig:
    """Static service parameters: one frozen config per deployment."""
    scenario: ScenarioConfig = field(default_factory=ScenarioConfig)
    scheduler: str = "admm_batched"     # SERVE_SCHEDULERS
    sched_cfg: Optional[SchedConfig] = None
    # Cache policy: a cell re-solves only when its worst-worker relative
    # channel movement since its last solve exceeds this. 0 = any change
    # re-solves (the cache-parity flag's setting); non-reporting cells
    # have movement exactly 0 and always stay cached.
    stale_threshold: float = 0.05
    # Seed each ADMM solve with the cell's previous exit multipliers
    # (admm_batched only; β is bitwise-unaffected — DESIGN.md §15)
    warm_duals: bool = True
    # Fraction of cells whose CSI report arrives each tick (streaming
    # ingestion model; 1.0 = every cell reports every tick)
    update_frac: float = 1.0
    min_bucket: int = MIN_BUCKET
    # P2 problem constants shared by every cell (paper §V operating point)
    k_weights: float = 3000.0
    p_max: float = 10.0
    noise_var: float = 1e-4
    D: int = 50890
    S: int = 1000
    kappa: int = 1000
    const: AnalysisConstants = field(
        default_factory=lambda: AnalysisConstants(rho1=200.0, G=1.0))

    def __post_init__(self):
        if self.scheduler not in SERVE_SCHEDULERS:
            raise ValueError(f"serve scheduler {self.scheduler!r} not in "
                             f"{SERVE_SCHEDULERS}")
        if not 0.0 <= self.update_frac <= 1.0:
            raise ValueError(f"update_frac must be in [0, 1], got "
                             f"{self.update_frac}")
        if self.stale_threshold < 0:
            raise ValueError(f"stale_threshold must be >= 0, got "
                             f"{self.stale_threshold}")

    @property
    def warm(self) -> bool:
        """Dual warm-starting actually active (admm only)."""
        return self.warm_duals and self.scheduler == "admm_batched"


class ServeState(NamedTuple):
    """Everything the service carries tick to tick. (cells, U) leaves
    except where noted; ``duals`` is an ``AdmmDuals`` pytree of
    (cells, U) leaves, or None when warm-starting is off."""
    fades: FadeState                   # incremental Gauss-Markov process
    gain: jnp.ndarray                  # static large-scale gain
    h_seen: jnp.ndarray                # newest reported |h| per cell
    h_solved: jnp.ndarray              # |h| each cached schedule used
    beta: jnp.ndarray                  # served schedules
    b_t: jnp.ndarray                   # (cells,) served power scalings
    rt: jnp.ndarray                    # (cells,) served R_t
    duals: Any                         # AdmmDuals | None
    tick: int                          # host-side tick counter


class TickStats(NamedTuple):
    """Host-side accounting for one service tick (cache-hit-rate and
    dirty-set telemetry; latency is timed by the caller around
    ``tick`` so the service itself stays timing-free)."""
    tick: int
    n_reported: int                    # cells whose CSI arrived
    n_dirty: int                       # cells past the staleness threshold
    n_solved: int                      # bucket size dispatched (pads incl.)
    hit_rate: float                    # 1 - dirty/cells
    mean_iters: float                  # ADMM outer iters (nan for greedy)
