"""repro.data.tokens — memory-mapped token shards for the zoo-train
data path (DESIGN.md §17).

The zoo-train CLI's default batches are synthetic token streams
(``launch.train.make_zoo_batch``); this module is the opt-in real-data
path behind ``--data``: a directory of flat binary token shards, memory-
mapped so a multi-GB corpus costs no resident memory, plus deterministic
per-worker window sampling.

Layout: ``<dir>/tokens_meta.json`` (dtype + shard file names) next to
``shard_*.tokens`` flat binaries. Shards are plain little-endian token
streams with NO framing — alignment is validated on open (a file whose
byte size is not a whole number of tokens is truncated or written with
the wrong dtype, and fails loudly instead of shifting every later token).

Sampling is keyed exactly like the round RNG (DESIGN.md §11/§14): round
``t`` folds the absolute round index into the data key, worker ``u``
folds again, so

* the same ``(key, t)`` draws the same (U, B, S) batch on any host, any
  mesh shape, and after any checkpoint resume (no data-iterator state to
  serialize), and
* workers draw independent streams — the non-IID knob is WHICH shards a
  worker samples from, left for the follow-up (ROADMAP).
"""
from __future__ import annotations

import json
import os
from typing import Sequence

import jax
import numpy as np

META_NAME = "tokens_meta.json"


class TokenShards:
    """Open memory-mapped token shards + deterministic batch sampling."""

    def __init__(self, directory: str, memmaps, dtype: np.dtype,
                 names: Sequence[str]):
        self.directory = directory
        self.memmaps = list(memmaps)
        self.dtype = dtype
        self.names = list(names)
        self.lengths = np.array([m.shape[0] for m in self.memmaps],
                                dtype=np.int64)

    # -- on-disk format ----------------------------------------------------

    @staticmethod
    def write(directory: str, shards, dtype=np.int32) -> str:
        """Write 1-D token arrays as flat binary shards + meta; returns
        the directory. (The export half of the format — tests and the
        smoke path build corpora from ``data.token_stream`` with it.)"""
        os.makedirs(directory, exist_ok=True)
        dtype = np.dtype(dtype)
        names = []
        for i, arr in enumerate(shards):
            a = np.ascontiguousarray(np.asarray(arr, dtype=dtype).ravel())
            name = f"shard_{i:05d}.tokens"
            a.tofile(os.path.join(directory, name))
            names.append(name)
        meta = {"dtype": dtype.name, "shards": names}
        with open(os.path.join(directory, META_NAME), "w") as f:
            json.dump(meta, f)
        return directory

    @classmethod
    def open(cls, directory: str) -> "TokenShards":
        """Memory-map every shard listed in the meta, validating token
        alignment (DESIGN.md §17)."""
        meta_p = os.path.join(directory, META_NAME)
        if not os.path.isfile(meta_p):
            raise FileNotFoundError(
                f"{directory!r} has no {META_NAME}; --data expects a "
                f"token-shard directory written by TokenShards.write")
        with open(meta_p) as f:
            meta = json.load(f)
        dtype = np.dtype(meta["dtype"])
        mms = []
        for name in meta["shards"]:
            p = os.path.join(directory, name)
            if not os.path.isfile(p):
                raise FileNotFoundError(
                    f"token shard {name!r} listed in {META_NAME} is "
                    f"missing from {directory!r}")
            size = os.path.getsize(p)
            if size == 0 or size % dtype.itemsize:
                raise ValueError(
                    f"token shard {name!r} is misaligned: {size} bytes "
                    f"is not a whole positive number of {dtype.name} "
                    f"tokens (itemsize {dtype.itemsize}) — the file is "
                    f"truncated or was written with a different dtype; "
                    f"re-export the shard or fix 'dtype' in {META_NAME}")
            mms.append(np.memmap(p, dtype=dtype, mode="r"))
        return cls(directory, mms, dtype, meta["shards"])

    @property
    def total_tokens(self) -> int:
        return int(self.lengths.sum())

    # -- sampling ----------------------------------------------------------

    def _check_window(self, S: int):
        need = S + 1
        short = np.flatnonzero(self.lengths < need)
        if short.size:
            i = int(short[0])
            raise ValueError(
                f"token shard {self.names[i]!r} holds "
                f"{int(self.lengths[i])} tokens but seq_len={S} sampling "
                f"needs windows of {need}; drop the shard from "
                f"{META_NAME} or lower --seq")

    def sample_worker(self, key, t: int, u: int, B: int, S: int):
        """Worker ``u``'s (B, S) next-token batch for round ``t``:
        windows at positions drawn from ``fold_in(fold_in(key, t), u)``
        — the same absolute-index keying as the round RNG, so resume
        needs no iterator state (DESIGN.md §17)."""
        self._check_window(S)
        k = jax.random.fold_in(jax.random.fold_in(key, t), u)
        ks, ko = jax.random.split(k)
        n = len(self.memmaps)
        sidx = np.asarray(jax.random.randint(ks, (B,), 0, n))
        span = self.lengths[sidx] - (S + 1)
        u01 = np.asarray(jax.random.uniform(ko, (B,), jax.numpy.float32))
        offs = np.minimum((u01 * (span + 1)).astype(np.int64), span)
        rows = np.stack([
            np.asarray(self.memmaps[int(si)][int(off):int(off) + S + 1])
            for si, off in zip(sidx, offs)])
        rows = rows.astype(np.int32)
        return rows[:, :-1], rows[:, 1:]

    def sample_zoo_batch(self, key, t: int, U: int, B: int, S: int):
        """(U, B, S) stacked per-worker batch dict for round ``t`` —
        drop-in for ``launch.train.make_zoo_batch`` (feed through
        ``ZooTrainRound.shard_batch``)."""
        toks, tgts = zip(*(self.sample_worker(key, t, u, B, S)
                           for u in range(U)))
        return {"tokens": np.stack(toks), "targets": np.stack(tgts)}


def write_token_shards(directory: str, shards, dtype=np.int32) -> str:
    """Module-level alias of :meth:`TokenShards.write`."""
    return TokenShards.write(directory, shards, dtype=dtype)
