from repro.data.mnist import load_mnist, partition_workers
from repro.data.synthetic import synthetic_mnist, token_stream

__all__ = ["load_mnist", "partition_workers", "synthetic_mnist",
           "token_stream"]
