from repro.data.mnist import load_mnist, partition_workers
from repro.data.synthetic import synthetic_mnist, token_stream
from repro.data.tokens import TokenShards, write_token_shards

__all__ = ["load_mnist", "partition_workers", "synthetic_mnist",
           "token_stream", "TokenShards", "write_token_shards"]
