"""Deterministic synthetic datasets (offline container, DESIGN.md §8).

- ``synthetic_mnist``: 28x28 grayscale "digits" built from per-class stroke
  templates + elastic jitter + pixel noise. Linearly non-trivial but
  learnable by the paper's 784-64-10 MLP — reproduces the qualitative
  training curves of §V without network access.
- ``token_stream``: integer token streams for LM smoke/integration tests.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_TEMPLATES = {}  # class -> (28,28) float template


def _digit_template(c: int) -> np.ndarray:
    """Procedural stroke template per class (deterministic)."""
    if c in _TEMPLATES:
        return _TEMPLATES[c]
    img = np.zeros((28, 28), np.float32)
    rng = np.random.default_rng(1000 + c)
    yy, xx = np.mgrid[0:28, 0:28]
    # class-specific arcs/strokes
    n_strokes = 2 + c % 3
    for s in range(n_strokes):
        cx, cy = rng.uniform(8, 20, 2)
        r = rng.uniform(4, 9)
        a0, a1 = sorted(rng.uniform(0, 2 * np.pi, 2))
        ang = np.arctan2(yy - cy, xx - cx)
        dist = np.hypot(yy - cy, xx - cx)
        arc = (np.abs(dist - r) < 1.6) & (ang > a0) & (ang < a1)
        img[arc] = 1.0
        if c % 2 == s % 2:  # add a bar
            x0 = int(rng.uniform(6, 18))
            img[6:22, x0:x0 + 2] = np.maximum(img[6:22, x0:x0 + 2], 0.9)
    img = img / max(img.max(), 1e-6)
    _TEMPLATES[c] = img
    return img


def synthetic_mnist(n_train: int = 60000, n_test: int = 10000,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]:
    """Returns (x_train (N,784) in [0,1], y_train, x_test, y_test)."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    y = rng.integers(0, 10, n).astype(np.int32)
    x = np.zeros((n, 28, 28), np.float32)
    shifts = rng.integers(-2, 3, (n, 2))
    noise = rng.normal(0, 0.15, (n, 28, 28)).astype(np.float32)
    scale = rng.uniform(0.8, 1.2, n).astype(np.float32)
    for c in range(10):
        idx = np.where(y == c)[0]
        t = _digit_template(c)
        x[idx] = t[None]
    # per-sample jitter: roll + scale + noise
    for i in range(n):
        x[i] = np.roll(np.roll(x[i], shifts[i, 0], 0), shifts[i, 1], 1)
    x = np.clip(x * scale[:, None, None] + noise, 0.0, 1.0)
    x = x.reshape(n, 784)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


def token_stream(n_seqs: int, seq_len: int, vocab: int,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Markov-ish token streams: (tokens, targets=next-token)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, (n_seqs, seq_len + 1), dtype=np.int64)
    # inject local structure: every other token repeats with offset
    base[:, 2::2] = (base[:, 1:-1:2] + 1) % vocab
    return base[:, :-1].astype(np.int32), base[:, 1:].astype(np.int32)
