"""MNIST loader: real IDX files if present under $MNIST_DIR, else the
deterministic synthetic substitute (offline container, DESIGN.md §8)."""
from __future__ import annotations

import gzip
import os
import struct
from typing import Tuple

import numpy as np

from repro.data.synthetic import synthetic_mnist


def _read_idx(path: str) -> np.ndarray:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        magic = struct.unpack(">HBB", f.read(4))
        _, dtype_code, ndim = magic
        dims = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype=np.uint8)
    return data.reshape(dims)


def load_mnist(mnist_dir: str = None) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray, np.ndarray]:
    """Returns (x_train (60000,784) float [0,1], y_train, x_test, y_test)."""
    mnist_dir = mnist_dir or os.environ.get("MNIST_DIR", "")
    names = [("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
             ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")]
    if mnist_dir and os.path.isdir(mnist_dir):
        found = []
        for img_n, lbl_n in names:
            for suffix in ("", ".gz"):
                ip = os.path.join(mnist_dir, img_n + suffix)
                lp = os.path.join(mnist_dir, lbl_n + suffix)
                if os.path.exists(ip) and os.path.exists(lp):
                    found.append((ip, lp))
                    break
        if len(found) == 2:
            (ti, tl), (vi, vl) = found
            xtr = _read_idx(ti).reshape(-1, 784).astype(np.float32) / 255.0
            ytr = _read_idx(tl).astype(np.int32)
            xte = _read_idx(vi).reshape(-1, 784).astype(np.float32) / 255.0
            yte = _read_idx(vl).astype(np.int32)
            return xtr, ytr, xte, yte
    return synthetic_mnist()


def partition_workers(x: np.ndarray, y: np.ndarray, n_workers: int,
                      samples_per_worker: int, *, iid: bool = True,
                      seed: int = 0):
    """Paper §V: randomly select K̄ distinct samples per worker.

    iid=False gives a label-skewed (2-class-dominant) non-iid split."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    if iid:
        for _ in range(n_workers):
            idx = rng.choice(len(x), samples_per_worker, replace=False)
            xs.append(x[idx])
            ys.append(y[idx])
    else:
        for w in range(n_workers):
            major = (2 * w) % 10, (2 * w + 1) % 10
            p = np.where(np.isin(y, major), 8.0, 1.0)
            p = p / p.sum()
            idx = rng.choice(len(x), samples_per_worker, replace=False, p=p)
            xs.append(x[idx])
            ys.append(y[idx])
    return np.stack(xs), np.stack(ys)   # (U, K̄, 784), (U, K̄)
