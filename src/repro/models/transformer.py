"""Unified decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

Layers are a ``lax.scan`` over stacked per-layer params (bounded HLO size and
compile time at 62+ layers), with optional ``jax.checkpoint`` remat in the
train path. Per-layer structural variation (local/global attention, hybrid
shared-attention application) is carried by scanned flag arrays.

Decode maintains functional KV/SSM caches stacked over layers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.dist.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed, he_init, init_embedding, init_mlp,
                                 mlp, rmsnorm, unembed)


# --- per-layer init -------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        a = cfg.attention
        p["attn_norm"] = jnp.zeros((d,), dtype)
        p["attn"] = (attn.init_mla(ks[0], d, a, dtype) if a.use_mla
                     else attn.init_gqa(ks[0], d, a, dtype))
        p["ffn_norm"] = jnp.zeros((d,), dtype)
        if fam == "moe":
            p["moe"] = moe_lib.init_moe(ks[1], d, cfg.d_ff, cfg.moe,
                                        gated=cfg.gated_mlp, dtype=dtype)
        else:
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype)
    elif fam in ("ssm", "hybrid"):
        p["ssm_norm"] = jnp.zeros((d,), dtype)
        p["ssm"] = ssm_lib.init_mamba2(ks[0], d, cfg.ssm, dtype)
    else:
        raise ValueError(fam)
    return p


def _init_shared_attn_block(key, cfg: ModelConfig, dtype):
    """Zamba2-style weight-tied attention+MLP block."""
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.zeros((d,), dtype),
        "attn": attn.init_gqa(ks[0], d, cfg.attention, dtype),
        "ffn_norm": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def layer_flags(cfg: ModelConfig):
    """Per-layer scanned flags."""
    L = cfg.num_layers
    if cfg.local_global_period:
        pp = cfg.local_global_period
        is_global = (jnp.arange(L) % pp) == (pp - 1)
    else:
        is_global = jnp.ones((L,), bool) if (cfg.attention is None
                                             or not cfg.attention.window) \
            else jnp.zeros((L,), bool)
    if cfg.hybrid_attn_every:
        apply_attn = (jnp.arange(L) % cfg.hybrid_attn_every) == \
            (cfg.hybrid_attn_every - 1)
    else:
        apply_attn = jnp.zeros((L,), bool)
    return {"is_global": is_global, "apply_attn": apply_attn}


def init_lm(key, cfg: ModelConfig):
    dtype = jnp.float32
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embedding": init_embedding(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                    fan_in=cfg.d_model, dtype=dtype)
    if cfg.family == "hybrid":
        params["shared_block"] = _init_shared_attn_block(ks[3], cfg, dtype)
    if cfg.family == "vlm":
        # stub projector bias marker (frontend itself is external, DESIGN §8)
        params["img_pos"] = (0.02 * jax.random.normal(
            ks[3], (cfg.num_image_tokens, cfg.d_model))).astype(dtype)
    return params


# --- layer application ------------------------------------------------------------

def _apply_layer_full(lp, x, cfg: ModelConfig, flags, positions, shared_block):
    """Full-sequence (train/prefill) layer. Returns (x, cache_seed, aux)."""
    fam = cfg.family
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    cache_seed = None
    if fam in ("dense", "vlm", "moe"):
        h = rmsnorm(x, lp["attn_norm"], eps)
        if cfg.attention.use_mla:
            o, (ckv, kr) = attn.mla_forward(lp["attn"], h, cfg.attention,
                                            positions=positions, eps=eps)
            cache_seed = (ckv, kr)
        else:
            o, (k, v) = attn.gqa_forward(lp["attn"], h, cfg.attention,
                                         positions=positions,
                                         is_global=flags["is_global"])
            cache_seed = (k, v)
        x = x + o
        h = rmsnorm(x, lp["ffn_norm"], eps)
        if fam == "moe":
            o, aux = moe_lib.moe_forward(lp["moe"], h, cfg.moe,
                                         gated=cfg.gated_mlp)
        else:
            o = mlp(lp["mlp"], h, cfg.gated_mlp)
        x = x + o
    else:  # ssm / hybrid
        h = rmsnorm(x, lp["ssm_norm"], eps)
        o, (conv_st, ssm_st) = ssm_lib.mamba2_forward(lp["ssm"], h, cfg.ssm,
                                                      eps=eps)
        x = x + o
        cache_seed = (conv_st, ssm_st)
        if fam == "hybrid":
            def with_attn(x):
                sb = shared_block
                h = rmsnorm(x, sb["attn_norm"], eps)
                o, (k, v) = attn.gqa_forward(sb["attn"], h, cfg.attention,
                                             positions=positions)
                x = x + o
                h = rmsnorm(x, sb["ffn_norm"], eps)
                x = x + mlp(sb["mlp"], h, cfg.gated_mlp)
                return x, (k, v)

            def without_attn(x):
                a = cfg.attention
                hd = cfg.head_dim
                B, S = x.shape[0], x.shape[1]
                z = jnp.zeros((B, S, a.num_kv_heads, hd), x.dtype)
                return x, (z, z)

            x, (k, v) = jax.lax.cond(flags["apply_attn"], with_attn,
                                     without_attn, x)
            cache_seed = cache_seed + (k, v)
    return x, cache_seed, aux


def remat_wrap(body, remat):
    """Wrap a scan body per the remat knob (DESIGN.md §16).

    ``remat`` is a bool (legacy: True == "full") or a policy name:
    "off" saves every residual (scan keeps all layer activations),
    "full" saves nothing (recompute the whole block in the backward),
    "dots" / "dots_no_batch" save matmul outputs only
    (``jax.checkpoint_policies``) — the middle ground that trades one
    extra gather+norm recompute for not holding attention internals."""
    if remat in (False, None, "off"):
        return body
    if remat in (True, "full"):
        return jax.checkpoint(body)
    policies = {
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch":
            jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    }
    if remat not in policies:
        raise ValueError(
            f"remat policy {remat!r} not in "
            f"{('off', 'full') + tuple(policies)}")
    return jax.checkpoint(body, policy=policies[remat])


def lm_forward(params, cfg: ModelConfig, tokens, *, image_embeds=None,
               remat=True, collect_cache=False, return_hidden=False,
               layer_resolver=None):
    """tokens: (B,S_text). Returns (logits_or_hidden, aux, cache or None).

    For vlm, image_embeds (B,N,d) are prepended (total seq = N + S_text).
    return_hidden=True skips the unembed (chunked-CE training path).

    ``layer_resolver`` maps the per-layer param slice to the form the
    block math consumes, INSIDE the scan body (and inside the remat
    boundary, so whatever it materializes is recomputed, not saved). The
    zoo-train path passes the all-gather resolver that turns model-axis
    weight shards into full per-layer weights one layer at a time —
    nothing dense at full model size ever exists (DESIGN.md §16)."""
    dtype = dtype_of(cfg)
    x = embed(params["embedding"], tokens, dtype) * math.sqrt(cfg.d_model)
    if cfg.family == "vlm":
        img = (image_embeds.astype(dtype)
               + params["img_pos"].astype(dtype)[None])
        x = jnp.concatenate([img, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    flags = layer_flags(cfg)
    shared_block = params.get("shared_block")

    def body(carry, xs):
        x, aux_acc = carry
        lp, fl = xs
        # scan-carry layout contract: activations ride the scan sharded
        # over workers on batch, replicated over model (a soft hint; see
        # DESIGN.md §16 — inside full-manual shard_map it degrades to a
        # no-op and the body IS already per-device).
        x = constrain(x, ("data", None, None))
        if layer_resolver is not None:
            lp = layer_resolver(lp)
        x, cache_seed, aux = _apply_layer_full(lp, x, cfg, fl, positions,
                                               shared_block)
        ys = cache_seed if collect_cache else None
        return (x, aux_acc + aux), ys

    body_fn = remat_wrap(body, remat)
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    (params["layers"], flags))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux, caches
    logits = unembed(x, embedding=params.get("embedding")
                     if cfg.tie_embeddings else None,
                     lm_head=params.get("lm_head"),
                     final_softcap=cfg.final_logit_softcap)
    return logits, aux, caches


# --- decode ------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Zero cache pytree, stacked over layers (leading L dim)."""
    L = cfg.num_layers
    dtype = dtype_of(cfg)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        a = cfg.attention
        if a.use_mla:
            return {
                "ckv": jnp.zeros((L, batch, seq_len, a.kv_lora_rank), dtype),
                "kr": jnp.zeros((L, batch, seq_len, a.qk_rope_dim), dtype),
            }
        hd = cfg.head_dim
        return {
            "k": jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype),
            "v": jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype),
        }
    s = cfg.ssm
    d_inner, n_heads, conv_dim = ssm_lib.ssm_dims(cfg.d_model, s)
    cache = {
        "conv": jnp.zeros((L, batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((L, batch, n_heads, s.head_dim, s.d_state),
                         jnp.float32),
    }
    if fam == "hybrid":
        a = cfg.attention
        hd = cfg.head_dim
        cache["k"] = jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype)
    return cache


def seed_cache_from_prefill(cfg: ModelConfig, cache, seeds, *,
                            start: int = 0):
    """Write prefill cache seeds into a zero decode cache at ``start``.

    ``seeds`` is the scan-stacked tuple ``lm_forward(collect_cache=True)``
    returns: per-layer ``(k, v)`` (GQA) or ``(ckv, kr)`` (MLA), each leaf
    shaped (L, B, T, ...). The forward already applies RoPE to K at the
    absolute positions 0..T-1 — identical values to what ``gqa_decode``
    would have written token-by-token — so seeding the first T slots and
    decoding from ``pos = start + T`` reproduces the full forward exactly
    (tests/test_decode_consistency.py, the vlm image-prefix path)."""
    fam = cfg.family
    if fam not in ("dense", "vlm", "moe"):
        raise NotImplementedError(
            f"prefill cache seeding is attention-only; family {fam!r} "
            "carries recurrent state that has no positional slot to seed")
    names = ("ckv", "kr") if cfg.attention.use_mla else ("k", "v")
    out = dict(cache)
    for name, seed in zip(names, seeds):
        at = (0, 0, start) + (0,) * (seed.ndim - 3)
        out[name] = jax.lax.dynamic_update_slice(
            cache[name], seed.astype(cache[name].dtype), at)
    return out


def cache_shardings_hints():
    """Dim hints for cache leaves: length over data, heads over model."""
    return {
        "k": (None, None, "data", "model", None),
        "v": (None, None, "data", "model", None),
        "ckv": (None, "data", None, "model"),
        "kr": (None, "data", None, None),
        "conv": (None, "data", None, "model"),
        "ssm": (None, "data", "model", None, None),
    }


def lm_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B,1) int32; pos: scalar int32. Returns (logits, new_cache)."""
    dtype = dtype_of(cfg)
    eps = cfg.norm_eps
    x = embed(params["embedding"], tokens, dtype) * math.sqrt(cfg.d_model)
    flags = layer_flags(cfg)
    shared_block = params.get("shared_block")
    fam = cfg.family

    def body(carry, xs):
        x = carry
        lp, fl, cache_l = xs
        new_cache = dict(cache_l)
        if fam in ("dense", "vlm", "moe"):
            h = rmsnorm(x, lp["attn_norm"], eps)
            if cfg.attention.use_mla:
                o, ckv, kr = attn.mla_decode(lp["attn"], h, cfg.attention,
                                             cache_ckv=cache_l["ckv"],
                                             cache_kr=cache_l["kr"],
                                             pos=pos, eps=eps)
                new_cache = {"ckv": ckv, "kr": kr}
            else:
                o, k, v = attn.gqa_decode(
                    lp["attn"], h, cfg.attention, cache_k=cache_l["k"],
                    cache_v=cache_l["v"], pos=pos,
                    is_global=fl["is_global"],
                    sharded_cache_chunks=cfg.decode_sharded_chunks)
                new_cache = {"k": k, "v": v}
            x = x + o
            h = rmsnorm(x, lp["ffn_norm"], eps)
            if fam == "moe":
                o, _ = moe_lib.moe_forward(lp["moe"], h, cfg.moe,
                                           gated=cfg.gated_mlp)
            else:
                o = mlp(lp["mlp"], h, cfg.gated_mlp)
            x = x + o
        else:
            h = rmsnorm(x, lp["ssm_norm"], eps)
            o, (conv_st, ssm_st) = ssm_lib.mamba2_decode(
                lp["ssm"], h, cfg.ssm, conv_state=cache_l["conv"],
                ssm_state=cache_l["ssm"], eps=eps)
            x = x + o
            new_cache = {"conv": conv_st.astype(cache_l["conv"].dtype),
                         "ssm": ssm_st}
            if fam == "hybrid":
                def with_attn(args):
                    x, k, v = args
                    sb = shared_block
                    h = rmsnorm(x, sb["attn_norm"], eps)
                    o, k, v = attn.gqa_decode(sb["attn"], h, cfg.attention,
                                              cache_k=k, cache_v=v, pos=pos)
                    x = x + o
                    h = rmsnorm(x, sb["ffn_norm"], eps)
                    x = x + mlp(sb["mlp"], h, cfg.gated_mlp)
                    return x, k, v

                x, k, v = jax.lax.cond(fl["apply_attn"], with_attn,
                                       lambda a: a,
                                       (x, cache_l["k"], cache_l["v"]))
                new_cache["k"] = k
                new_cache["v"] = v
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], flags, cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, embedding=params.get("embedding")
                     if cfg.tie_embeddings else None,
                     lm_head=params.get("lm_head"),
                     final_softcap=cfg.final_logit_softcap)
    return logits, new_cache
