"""The paper's own learning model (§V): MLP 784-64-10, ReLU, cross-entropy.

D = 784*64 + 64 + 64*10 + 10 = 50,890 parameters — matching the paper exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import he_init


def init_mlp_mnist(key, d_in=784, d_hidden=64, n_classes=10):
    k1, k2 = jax.random.split(key)
    return {
        "w1": he_init(k1, (d_in, d_hidden)),
        "b1": jnp.zeros((d_hidden,)),
        "w2": he_init(k2, (d_hidden, n_classes)),
        "b2": jnp.zeros((n_classes,)),
    }


def mlp_mnist_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_mnist_loss(params, x, y):
    logits = mlp_mnist_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def mlp_mnist_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_mnist_logits(params, x), axis=-1) == y)


def param_dim(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
