"""Whisper-style encoder-decoder transformer backbone.

The mel+conv frontend is a STUB (assignment carve-out): the model consumes
precomputed frame embeddings (B, S_enc, d_model). Sinusoidal positions on the
encoder, learned positions on the decoder, no RoPE (faithful to Whisper).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, dtype_of
from repro.models import attention as attn
from repro.models.layers import (embed, he_init, init_embedding, init_mlp,
                                 mlp, rmsnorm, sinusoidal_positions, unembed)

MAX_DECODE_POSITIONS = 32768 * 17  # covers decode_32k; learned table


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "attn_norm": jnp.zeros((d,), dtype),
        "attn": attn.init_gqa(ks[0], d, cfg.attention, dtype),
        "ffn_norm": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "attn_norm": jnp.zeros((d,), dtype),
        "attn": attn.init_gqa(ks[0], d, cfg.attention, dtype),
        "cross_norm": jnp.zeros((d,), dtype),
        "cross": attn.init_gqa(ks[1], d, cfg.attention, dtype),
        "ffn_norm": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.float32
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embedding": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "pos_embedding": (0.01 * jax.random.normal(
            ks[3], (4096, cfg.d_model))).astype(dtype),  # learned dec pos (mod table)
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def encode(params, cfg: ModelConfig, frames, *, layer_resolver=None):
    """frames: (B, S_enc, d) stub embeddings -> encoder states (B,S_enc,d)."""
    dtype = dtype_of(cfg)
    x = frames.astype(dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        if layer_resolver is not None:
            lp = layer_resolver(lp)
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        o, _ = attn.gqa_forward(lp["attn"], h, cfg.attention,
                                positions=positions, causal=False,
                                use_rope=False)
        x = x + o
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, cfg.gated_mlp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_positions(params, positions, dtype):
    table = params["pos_embedding"]
    return table[positions % table.shape[0]].astype(dtype)


def decode_full(params, cfg: ModelConfig, tokens, enc_out, *, remat=True,
                return_hidden=False, layer_resolver=None):
    """Teacher-forced decoder pass. tokens: (B,S_dec). Returns logits."""
    dtype = dtype_of(cfg)
    x = embed(params["embedding"], tokens, dtype) * math.sqrt(cfg.d_model)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = x + _dec_positions(params, positions, dtype)[None]
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(x, lp):
        if layer_resolver is not None:
            lp = layer_resolver(lp)
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        o, _ = attn.gqa_forward(lp["attn"], h, cfg.attention,
                                positions=positions, causal=True,
                                use_rope=False)
        x = x + o
        h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        o, _ = attn.gqa_forward(lp["cross"], h, cfg.attention,
                                positions=positions, causal=False,
                                use_rope=False, kv=enc_out,
                                kv_positions=enc_pos)
        x = x + o
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, cfg.gated_mlp)
        return x, None

    from repro.models.transformer import remat_wrap
    body_fn = remat_wrap(body, remat)
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return unembed(x, embedding=params["embedding"])


def init_encdec_cache(cfg: ModelConfig, batch: int, seq_len: int):
    L = cfg.num_layers
    a = cfg.attention
    hd = cfg.head_dim
    dtype = dtype_of(cfg)
    return {
        "k": jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, seq_len, a.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.encoder_seq_len, a.num_kv_heads,
                              hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.encoder_seq_len, a.num_kv_heads,
                              hd), dtype),
    }


def seed_cross_cache(params, cfg: ModelConfig, cache, enc_out):
    """Fill cross-attention K/V from encoder output (once, at prefill)."""
    def per_layer(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out,
                       lp["cross"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out,
                       lp["cross"]["wv"].astype(enc_out.dtype))
        return k, v

    ck, cv = jax.vmap(per_layer)(params["layers"])
    cache = dict(cache)
    cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
    cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    return cache


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One decoder token with self cache + precomputed cross K/V."""
    dtype = dtype_of(cfg)
    x = embed(params["embedding"], tokens, dtype) * math.sqrt(cfg.d_model)
    x = x + _dec_positions(params, jnp.full((1,), pos, jnp.int32), dtype)[None]

    def body(x, xs):
        lp, cache_l = xs
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        o, k, v = attn.gqa_decode(lp["attn"], h, cfg.attention,
                                  cache_k=cache_l["k"], cache_v=cache_l["v"],
                                  pos=pos, use_rope=False)
        x = x + o
        h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        o, _, _ = attn.gqa_decode(lp["cross"], h, cfg.attention,
                                  cache_k=cache_l["cross_k"],
                                  cache_v=cache_l["cross_v"], pos=pos,
                                  use_rope=False, cross=True)
        x = x + o
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, cfg.gated_mlp)
        return x, {"k": k, "v": v, "cross_k": cache_l["cross_k"],
                   "cross_v": cache_l["cross_v"]}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, embedding=params["embedding"]), new_cache
