"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
attention-like term + inter-chunk state recurrence (lax.scan over chunks).
Decode is the O(1) recurrent update.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.dist.sharding import constrain
from repro.models.layers import he_init, rmsnorm


def ssm_dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, d_model: int, s: SSMConfig, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = ssm_dims(d_model, s)
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    p = {
        "in_proj": he_init(ks[0], (d_model, d_in_proj), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim))
                   * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (n_heads,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(dtype),
        "gate_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": he_init(ks[3], (d_inner, d_model), dtype=dtype),
    }
    return p


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    z, xs, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + n_groups * d_state,
         2 * d_inner + 2 * n_groups * d_state],
        axis=-1)
    return z, xs, B, C, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """xbc: (B,S,conv_dim); depthwise causal conv width W.

    conv_state: (B, W-1, conv_dim) history for decode/chunked prefill."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xpad = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xpad[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(W))
    new_state = xpad[:, -(W - 1):] if W > 1 else pad
    return jax.nn.silu(out + conv_b), new_state


def _segsum(x):
    """x: (..., T). Returns (..., T, T) lower-tri cumulative sums."""
    T = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    d = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD scan (Mamba2 alg. 1, einsum form).

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B,C: (b, s, g, n).
    Returns y (b,s,h,p), final_state (b,h,p,n)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    rep = h // g
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    dA = dtc * (-jnp.exp(A.astype(jnp.float32)))        # (b,nc,l,h) negative
    dA_cs = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # (b,nc,h,l,l)
    Bh = jnp.repeat(Bc, rep, axis=3)                    # (b,nc,l,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)   # (b,nc,h,l,l)
    scores = scores * L
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp", scores, dtc, xc)
    # chunk end-states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        Bh, decay_states, dtc, xc)       # (b,nc,h,p,n)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,nc,h)

    def step(carry, xs):
        st, dec = xs                                    # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit state BEFORE chunk

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)
    # inter-chunk contribution
    state_decay = jnp.exp(dA_cs)                        # (b,nc,l,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Ch.astype(jnp.float32), prev_states, state_decay)
    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def mamba2_forward(p, x, s: SSMConfig, *, init_conv=None, init_ssm=None,
                   eps=1e-6):
    """x: (B,S,d). Returns (out, (conv_state, ssm_state))."""
    d_model = x.shape[-1]
    d_inner, n_heads, conv_dim = ssm_dims(d_model, s)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    zxbcdt = constrain(zxbcdt, ("data", None, "model"))
    z, xs, B, C, dt = _split_proj(zxbcdt, d_inner, s.n_groups, s.d_state,
                                  n_heads)
    xbc = jnp.concatenate([xs, B, C], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), init_conv)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state],
                         axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    bsz, S = x.shape[0], x.shape[1]
    xh = xs.reshape(bsz, S, n_heads, s.head_dim)
    Bg = B.reshape(bsz, S, s.n_groups, s.d_state)
    Cg = C.reshape(bsz, S, s.n_groups, s.d_state)
    chunk = min(s.chunk_size, S)
    while S % chunk:
        chunk //= 2
    # ssd_chunked expects A_log such that dA = dt * (-exp(A_log)).
    y, ssm_state = ssd_chunked(xh, dt, p["A_log"].astype(jnp.float32),
                               Bg, Cg, chunk, init_state=init_ssm)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return constrain(out, ("data", None, None)), (conv_state, ssm_state)


def mamba2_decode(p, x, s: SSMConfig, *, conv_state, ssm_state, eps=1e-6):
    """Single-token recurrent step. x: (B,1,d).

    conv_state: (B, W-1, conv_dim); ssm_state: (B,h,p,n) float32."""
    d_model = x.shape[-1]
    d_inner, n_heads, conv_dim = ssm_dims(d_model, s)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xs, B, C, dt = _split_proj(zxbcdt, d_inner, s.n_groups, s.d_state,
                                  n_heads)
    xbc = jnp.concatenate([xs, B, C], axis=-1)          # (B,1,conv_dim)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), conv_state)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state],
                         axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (h,)
    rep = n_heads // s.n_groups
    xh = xs[:, 0].reshape(-1, n_heads, s.head_dim).astype(jnp.float32)
    Bg = B[:, 0].reshape(-1, s.n_groups, s.d_state).astype(jnp.float32)
    Cg = C[:, 0].reshape(-1, s.n_groups, s.d_state).astype(jnp.float32)
    Bh = jnp.repeat(Bg, rep, axis=1)                    # (B,h,n)
    Ch = jnp.repeat(Cg, rep, axis=1)
    decay = jnp.exp(dt * A[None])                       # (B,h)
    ssm_state = (ssm_state * decay[..., None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh))
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Ch)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(x.shape[0], 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (conv_state, ssm_state)
