"""Model registry: a uniform interface over all architecture families.

``build_model(cfg)`` returns a ``Model`` with:
  init(rng) -> params
  loss_fn(params, batch) -> (scalar loss, aux)
  forward(params, batch) -> logits
  prefill(params, batch) -> (logits, cache)
  init_cache(batch_size, seq_len) -> cache pytree
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  input_specs(shape) -> dict of ShapeDtypeStructs (dry-run stand-ins)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, dtype_of
from repro.models import encdec, transformer
from repro.models.mlp_mnist import init_mlp_mnist, mlp_mnist_logits, mlp_mnist_loss


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    forward: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable
    input_specs: Callable


def cross_entropy(logits, targets, mask=None):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def _lm_model(cfg: ModelConfig) -> Model:
    is_vlm = cfg.family == "vlm"

    def init(rng):
        return transformer.init_lm(rng, cfg)

    def forward(params, batch, remat=True, layer_resolver=None):
        logits, aux, _ = transformer.lm_forward(
            params, cfg, batch["tokens"],
            image_embeds=batch.get("image_embeds"), remat=remat,
            layer_resolver=layer_resolver)
        return logits

    def loss_fn(params, batch, remat=True, layer_resolver=None):
        hidden, aux, _ = transformer.lm_forward(
            params, cfg, batch["tokens"],
            image_embeds=batch.get("image_embeds"), remat=remat,
            return_hidden=True, layer_resolver=layer_resolver)
        tgt = batch["targets"]
        B = tgt.shape[0]
        if is_vlm:  # image positions carry no LM loss
            n_img = cfg.num_image_tokens
            tgt = jnp.concatenate(
                [jnp.zeros((B, n_img), tgt.dtype), tgt], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, n_img), jnp.float32),
                 jnp.ones((B, tgt.shape[1] - n_img), jnp.float32)], axis=1)
        else:
            mask = None
        from repro.models.layers import chunked_cross_entropy
        loss = chunked_cross_entropy(
            hidden, tgt,
            embedding=params["embedding"] if cfg.tie_embeddings else None,
            lm_head=params.get("lm_head"),
            final_softcap=cfg.final_logit_softcap, mask=mask)
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_loss * aux / cfg.num_layers
        return loss, {"aux": aux}

    def prefill(params, batch):
        logits, _, caches = transformer.lm_forward(
            params, cfg, batch["tokens"],
            image_embeds=batch.get("image_embeds"), remat=False,
            collect_cache=True)
        return logits, caches

    def init_cache(batch_size, seq_len):
        return transformer.init_lm_cache(cfg, batch_size, seq_len)

    def decode_step(params, cache, tokens, pos):
        return transformer.lm_decode_step(params, cfg, cache, tokens, pos)

    def input_specs(shape: InputShape):
        return lm_input_specs(cfg, shape)

    return Model(cfg, init, loss_fn, forward, prefill, init_cache,
                 decode_step, input_specs)


def _encdec_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return encdec.init_encdec(rng, cfg)

    def forward(params, batch, remat=True, layer_resolver=None):
        enc = encdec.encode(params, cfg, batch["frames"],
                            layer_resolver=layer_resolver)
        return encdec.decode_full(params, cfg, batch["tokens"], enc,
                                  remat=remat, layer_resolver=layer_resolver)

    def loss_fn(params, batch, remat=True, layer_resolver=None):
        from repro.models.layers import chunked_cross_entropy
        enc = encdec.encode(params, cfg, batch["frames"],
                            layer_resolver=layer_resolver)
        hidden = encdec.decode_full(params, cfg, batch["tokens"], enc,
                                    remat=remat, return_hidden=True,
                                    layer_resolver=layer_resolver)
        loss = chunked_cross_entropy(hidden, batch["targets"],
                                     embedding=params["embedding"])
        return loss, {}

    def prefill(params, batch):
        enc = encdec.encode(params, cfg, batch["frames"])
        cache = encdec.init_encdec_cache(cfg, batch["frames"].shape[0],
                                         batch["tokens"].shape[1])
        cache = encdec.seed_cross_cache(params, cfg, cache, enc)
        logits = encdec.decode_full(params, cfg, batch["tokens"], enc,
                                    remat=False)
        return logits, cache

    def init_cache(batch_size, seq_len):
        return encdec.init_encdec_cache(cfg, batch_size, seq_len)

    def decode_step(params, cache, tokens, pos):
        return encdec.encdec_decode_step(params, cfg, cache, tokens, pos)

    def input_specs(shape: InputShape):
        return lm_input_specs(cfg, shape)

    return Model(cfg, init, loss_fn, forward, prefill, init_cache,
                 decode_step, input_specs)


def _mlp_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return init_mlp_mnist(rng, cfg.d_ff, cfg.d_model, cfg.vocab_size)

    def loss_fn(params, batch, remat=False, layer_resolver=None):
        return mlp_mnist_loss(params, batch["x"], batch["y"]), {}

    def forward(params, batch, remat=False, layer_resolver=None):
        return mlp_mnist_logits(params, batch["x"])

    def unsupported(*a, **k):
        raise NotImplementedError("mnist-mlp has no decode path")

    def input_specs(shape: InputShape):
        B = shape.global_batch
        return {"x": jax.ShapeDtypeStruct((B, cfg.d_ff), jnp.float32),
                "y": jax.ShapeDtypeStruct((B,), jnp.int32)}

    return Model(cfg, init, loss_fn, forward, unsupported, unsupported,
                 unsupported, input_specs)


def lm_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dtype = dtype_of(cfg)
    tok = jnp.int32
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct(
                        (B, cfg.encoder_seq_len, cfg.d_model), dtype),
                    "tokens": jax.ShapeDtypeStruct((B, S), tok),
                    "targets": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.family == "vlm":
            s_text = S - cfg.num_image_tokens
            return {"image_embeds": jax.ShapeDtypeStruct(
                        (B, cfg.num_image_tokens, cfg.d_model), dtype),
                    "tokens": jax.ShapeDtypeStruct((B, s_text), tok),
                    "targets": jax.ShapeDtypeStruct((B, s_text), tok)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok),
                "targets": jax.ShapeDtypeStruct((B, S), tok)}
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "mlp":
        return _mlp_model(cfg)
    if cfg.family == "audio":
        return _encdec_model(cfg)
    return _lm_model(cfg)
