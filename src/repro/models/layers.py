"""Shared building blocks: norms, inits, RoPE, MLPs, embeddings."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


def he_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / max(1, fan_in))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def lecun_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(1.0 / max(1, fan_in))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# --- RoPE ---------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]                 # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, dim: int):
    pos = jnp.arange(num_pos, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    emb = jnp.zeros((num_pos, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# --- MLP ----------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w1": he_init(ks[0], (d_model, d_ff), dtype=dtype),
         "w2": he_init(ks[1], (d_ff, d_model), dtype=dtype)}
    if gated:
        p["w3"] = he_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp(params, x, gated: bool):
    h = x @ params["w1"].astype(x.dtype)
    h = constrain(h, ("data", None, "model"))
    if gated:
        h = jax.nn.silu(h) * (x @ params["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    out = h @ params["w2"].astype(x.dtype)
    return constrain(out, ("data", None, "data"))


# --- Embedding ----------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return lecun_init(key, (vocab, d_model), fan_in=d_model, dtype=dtype)


def embed(embedding, tokens, dtype):
    out = jnp.take(embedding, tokens, axis=0).astype(dtype)
    return constrain(out, ("data", None, None))


def unembed(x, embedding=None, lm_head=None, final_softcap: float = 0.0):
    if lm_head is not None:
        logits = x @ lm_head.astype(x.dtype)
    else:
        logits = x @ embedding.T.astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), final_softcap)
    return constrain(logits, ("data", None, "model"))


def chunked_cross_entropy(x, targets, *, embedding=None, lm_head=None,
                          final_softcap: float = 0.0, mask=None,
                          seq_chunk: int = 512):
    """Cross-entropy over vocab WITHOUT materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside one scan
    step (remat'd in the backward pass). Required for 256k-vocab training
    shapes to fit HBM (DESIGN.md §6). x: (B,S,d); targets: (B,S)."""
    B, S, _ = x.shape
    cs = min(seq_chunk, S)
    while S % cs:
        cs //= 2
    nb = S // cs
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    xc = x.reshape(B, nb, cs, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nb, cs).transpose(1, 0, 2)
    mc = mask.reshape(B, nb, cs).transpose(1, 0, 2)

    def body(carry, xs):
        xb, tb, mb = xs
        logits = unembed(xb, embedding=embedding, lm_head=lm_head,
                         final_softcap=final_softcap)
        # nll = logsumexp(logits) - logits[target]: never materializes logp
        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(logits, tb[..., None],
                                     axis=-1)[..., 0].astype(jnp.float32)
        nll = lse - picked
        return carry + jnp.sum(nll * mb), None

    if nb <= 1:
        total, _ = body(jnp.zeros((), jnp.float32), (xc[0], tc[0], mc[0]))
    else:
        total, _ = jax.lax.scan(jax.checkpoint(body),
                                jnp.zeros((), jnp.float32), (xc, tc, mc))
    return total / jnp.clip(jnp.sum(mask), 1.0)
