"""Mixture-of-Experts: top-k router + capacity-based dispatch.

Dispatch strategy (§Perf iteration — see EXPERIMENTS.md):
The naive global scatter-add into an (E, C, d) buffer forces GSPMD to
replicate the buffer and all-reduce partial scatters (~50 GB/layer at 1M
tokens). Instead, when a shardable data axis is live, dispatch runs inside a
``shard_map`` manual over (pod, data): every shard computes positions with a
LOCAL cumsum and scatters into its LOCAL (E, C_loc, d) buffer — zero
cross-shard traffic for dispatch/combine; only the expert einsum itself
communicates (weights are expert/ff-sharded over `model`).

Shared (always-on) experts are a plain dense MLP (DeepSeek-V2 style).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.dist.sharding import constrain
from repro.models.layers import he_init, init_mlp, mlp


def init_moe(key, d_model: int, d_ff: int, m: MoEConfig, gated=True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = {
        "router": he_init(ks[0], (d_model, m.num_experts), dtype=dtype),
        "ew1": he_init(ks[1], (m.num_experts, d_model, d_ff), fan_in=d_model,
                       dtype=dtype),
        "ew2": he_init(ks[2], (m.num_experts, d_ff, d_model), fan_in=d_ff,
                       dtype=dtype),
    }
    if gated:
        p["ew3"] = he_init(ks[3], (m.num_experts, d_model, d_ff),
                           fan_in=d_model, dtype=dtype)
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d_model, d_ff * m.num_shared_experts,
                               gated, dtype=dtype)
    return p


def _route(logits, top_k: int):
    """Returns (weights (T,k), idx (T,k), aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return w, idx, aux


def _moe_tokens(p, xf, m: MoEConfig, gated: bool, capacity: int):
    """Core MoE over flat tokens xf (T, d). Dispatch indices are computed
    from THESE tokens only — call per shard for locality."""
    T, d = xf.shape
    k = m.top_k
    E = m.num_experts
    logits = xf @ p["router"].astype(xf.dtype)                  # (T,E)
    w, idx, aux = _route(logits, k)                             # (T,k)
    if capacity <= 0:
        capacity = int(math.ceil(T * k / E * m.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)
    flat_idx = idx.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)       # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)       # before me
    pos = jnp.take_along_axis(pos_in_expert, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < capacity                                       # drop overflow
    w_flat = w.reshape(-1) * keep
    buf = jnp.zeros((E, capacity, d), xf.dtype)
    src = jnp.repeat(xf, k, axis=0)                             # (T*k, d)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    buf = buf.at[flat_idx, safe_pos].add(src * keep[:, None].astype(xf.dtype))
    # expert MLPs: batched over E; weights sharded over `model` (auto axis)
    h = jnp.einsum("ecd,edf->ecf", buf, p["ew1"].astype(xf.dtype))
    if gated:
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf,
                                        p["ew3"].astype(xf.dtype))
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["ew2"].astype(xf.dtype))
    gathered = out_buf[flat_idx, safe_pos]                      # (T*k, d)
    combined = (gathered * w_flat[:, None].astype(xf.dtype)).reshape(T, k, d)
    out = jnp.sum(combined, axis=1)
    if m.num_shared_experts:
        out = out + mlp(p["shared"], xf[None], gated)[0]
    return out, aux.astype(jnp.float32)


def _auto_worker_axes():
    """(pod, data) axes that are live AND still GSPMD-auto (not already
    manual from an enclosing shard_map)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return (), 1, None
    types = dict(zip(mesh.axis_names, mesh.axis_types))
    axes = tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names
                 and "Manual" not in str(types[ax]))
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    return axes, n, mesh


def moe_forward(p, x, m: MoEConfig, *, gated=True,
                capacity: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d). Returns (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    axes, W, mesh = _auto_worker_axes()
    if axes and W > 1 and T % W == 0 and (T // W) >= 64:
        spec = P(axes if len(axes) > 1 else axes[0])

        def local_fn(xf):
            out, aux = _moe_tokens(p, xf, m, gated, capacity)
            return out, jax.lax.pmean(aux, axes)

        xf = x.reshape(T, d)
        out, aux = jax.shard_map(
            local_fn, mesh=mesh, axis_names=set(axes),
            in_specs=(spec,), out_specs=(spec, P()),
            check_vma=False)(xf)
        return out.reshape(B, S, d), aux
    out, aux = _moe_tokens(p, x.reshape(T, d), m, gated, capacity)
    return out.reshape(B, S, d), aux
