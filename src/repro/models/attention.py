"""Attention: GQA/MHA (RoPE, sliding window, logit softcap) and MLA.

Full-sequence attention (train/prefill) is blockwise over query blocks
(lax.scan) so no S x S score tensor is ever materialized — required for the
32k prefill shapes. Decode attends a single query over the KV cache; the MLA
decode path uses the absorbed-latent formulation (scores directly against the
cached latent, DeepSeek-V2 style).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.dist.sharding import constrain
from repro.models.layers import apply_rope, he_init, rmsnorm, softcap


# --- init ----------------------------------------------------------------------

def init_gqa(key, d_model: int, a: AttentionConfig, dtype=jnp.float32):
    hd = a.head_dim if a.head_dim else d_model // a.num_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": he_init(ks[0], (d_model, a.num_heads, hd), fan_in=d_model, dtype=dtype),
        "wk": he_init(ks[1], (d_model, a.num_kv_heads, hd), fan_in=d_model, dtype=dtype),
        "wv": he_init(ks[2], (d_model, a.num_kv_heads, hd), fan_in=d_model, dtype=dtype),
        "wo": he_init(ks[3], (a.num_heads, hd, d_model),
                      fan_in=a.num_heads * hd, dtype=dtype),
    }


def init_mla(key, d_model: int, a: AttentionConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    qd = a.qk_nope_dim + a.qk_rope_dim
    p = {}
    if a.q_lora_rank:
        p["w_dq"] = he_init(ks[0], (d_model, a.q_lora_rank), dtype=dtype)
        p["w_uq"] = he_init(ks[1], (a.q_lora_rank, a.num_heads, qd),
                            fan_in=a.q_lora_rank, dtype=dtype)
        p["q_norm"] = jnp.zeros((a.q_lora_rank,), dtype)
    else:
        p["wq"] = he_init(ks[1], (d_model, a.num_heads, qd), fan_in=d_model,
                          dtype=dtype)
    p["w_dkv"] = he_init(ks[2], (d_model, a.kv_lora_rank), dtype=dtype)
    p["w_kr"] = he_init(ks[3], (d_model, a.qk_rope_dim), dtype=dtype)
    p["kv_norm"] = jnp.zeros((a.kv_lora_rank,), dtype)
    p["w_uk"] = he_init(ks[4], (a.kv_lora_rank, a.num_heads, a.qk_nope_dim),
                        fan_in=a.kv_lora_rank, dtype=dtype)
    p["w_uv"] = he_init(ks[5], (a.kv_lora_rank, a.num_heads, a.v_head_dim),
                        fan_in=a.kv_lora_rank, dtype=dtype)
    p["wo"] = he_init(ks[6], (a.num_heads, a.v_head_dim, d_model),
                      fan_in=a.num_heads * a.v_head_dim, dtype=dtype)
    return p


# --- core blockwise attention ---------------------------------------------------

def _block_attend(q, k, v, q_pos, k_pos, *, scale, causal, window, is_global,
                  cap: float, kv_valid=None):
    """One query block against all keys.

    q: (B, Tq, H, hd); k/v: (B, S, KV, hd-like). Returns (B, Tq, H, vd).
    window/is_global may be traced scalars; mask fuses (no S x S global tensor).
    """
    B, Tq, H, _ = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Tq, KV, rep, q.shape[-1])
    # q-major score layout (b,t,k,r,s): output einsum lands directly in the
    # (B,Tq,H,hd) layout — avoids an SPMD-hostile transpose that forced
    # involuntary full rematerialization (§Perf iteration 1)
    scores = jnp.einsum("btkrh,bskh->btkrs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    mask = jnp.ones((Tq, k.shape[1]), bool)
    delta = q_pos[:, None] - k_pos[None, :]             # (Tq, S)
    if causal:
        mask &= delta >= 0
    if window is not None:
        in_window = delta < window
        mask &= jnp.where(is_global, True, in_window) if is_global is not None \
            else in_window
    # scores layout: (B, Tq, KV, rep, S)
    if kv_valid is not None:                            # (B, S) valid entries
        mask = (mask[None, :, None, None, :]
                & kv_valid[:, None, None, None, :])     # (B,Tq,1,1,S)
    else:
        mask = mask[None, :, None, None, :]             # (1,Tq,1,1,S)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("btkrs,bskh->btkrh", w, v)
    return out.reshape(B, Tq, H, v.shape[-1])


def blockwise_attention(q, k, v, q_positions, k_positions, *, scale,
                        causal=True, window=None, is_global=None, cap=0.0,
                        block_size=512, kv_valid=None):
    """Scan over query blocks; each block sees all keys (masked)."""
    B, S, H, hd = q.shape
    bs = min(block_size, S)
    while S % bs:
        bs //= 2
    nb = S // bs
    if nb <= 1:
        return _block_attend(q, k, v, q_positions, k_positions, scale=scale,
                             causal=causal, window=window, is_global=is_global,
                             cap=cap, kv_valid=kv_valid)
    qb = q.reshape(B, nb, bs, H, hd).transpose(1, 0, 2, 3, 4)
    pb = q_positions.reshape(nb, bs)

    def step(_, xs):
        qblk, pblk = xs
        o = _block_attend(qblk, k, v, pblk, k_positions, scale=scale,
                          causal=causal, window=window, is_global=is_global,
                          cap=cap, kv_valid=kv_valid)
        return None, o

    # flash-style: recompute block scores in the backward pass — only the
    # (B, bs, H, hd) block output is ever live across blocks
    _, ob = jax.lax.scan(jax.checkpoint(step), None, (qb, pb))
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, S, H, -1)


# --- GQA forward (train/prefill) -----------------------------------------------

def gqa_forward(p, x, a: AttentionConfig, *, positions, causal=True,
                is_global=None, use_rope=True, kv=None, kv_positions=None):
    """x: (B,S,d). Returns (out, (k, v)) — k/v returned for cache seeding.

    kv: optional encoder output (B, S_enc, d) for cross-attention."""
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    src = kv if kv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    q = constrain(q, ("data", None, "model", None))
    k = constrain(k, ("data", None, "model", None))
    v = constrain(v, ("data", None, "model", None))
    k_pos = kv_positions if kv_positions is not None else positions
    if use_rope:
        q = apply_rope(q, positions, a.rope_theta)
        if kv is None:
            k = apply_rope(k, k_pos, a.rope_theta)
    window = a.window if a.window else None
    out = blockwise_attention(
        q, k, v, positions, k_pos, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, is_global=is_global, cap=a.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(out, ("data", None, None)), (k, v)


def decode_attention_sharded(q, k, v, pos, *, scale, window=None,
                             is_global=None, cap=0.0, n_chunks=16):
    """Flash-decoding-style single-token attention over a LENGTH-SHARDED
    cache (§Perf optimization): per-chunk partial (max, exp-sum, weighted-V)
    reduced across chunks — the cross-device traffic is the (B,H,hd)
    partials instead of an all-gather of the full K/V.

    q: (B,1,H,hd); k/v: (B,S,KV,hd) with S sharded over `data`."""
    from repro.dist.sharding import constrain
    B, S, KV, hd = k.shape
    H = q.shape[2]
    rep = H // KV
    while S % n_chunks:
        n_chunks //= 2
    cl = S // n_chunks
    kc = constrain(k.reshape(B, n_chunks, cl, KV, hd),
                   (None, "data", None, "model", None))
    vc = constrain(v.reshape(B, n_chunks, cl, KV, hd),
                   (None, "data", None, "model", None))
    qg = q.reshape(B, KV, rep, hd)
    scores = jnp.einsum("bkrh,bnskh->bnkrs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale    # (B,nc,KV,rep,cl)
    scores = softcap(scores, cap)
    k_pos = jnp.arange(S, dtype=jnp.int32).reshape(n_chunks, cl)
    mask = k_pos <= pos                                    # causal
    if window is not None:
        in_w = (pos - k_pos) < window
        mask = mask & (jnp.where(is_global, True, in_w)
                       if is_global is not None else in_w)
    scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    m_part = jnp.max(scores, axis=-1)                      # (B,nc,KV,rep)
    m_glob = jnp.max(m_part, axis=1, keepdims=True)        # cross-chunk
    e = jnp.exp(scores - m_glob[..., None])
    denom = jnp.sum(e, axis=(1, 4))                        # (B,KV,rep)
    num = jnp.einsum("bnkrs,bnskh->bkrh", e, vc.astype(jnp.float32))
    out = num / denom[..., None]
    return out.reshape(B, 1, H, hd).astype(v.dtype)


def gqa_decode(p, x, a: AttentionConfig, *, cache_k, cache_v, pos,
               is_global=None, use_rope=True, cross=False,
               sharded_cache_chunks: int = 0):
    """x: (B,1,d); cache_k/v: (B,S,KV,hd). Returns (out, new_k, new_v)."""
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    B, S = cache_k.shape[0], cache_k.shape[1]
    q_pos = jnp.full((1,), pos, jnp.int32)
    if use_rope:
        q = apply_rope(q, q_pos, a.rope_theta)
    if cross:
        k, v = cache_k, cache_v
        kv_valid = None
        k_pos = jnp.arange(S, dtype=jnp.int32)
        causal = False
    else:
        knew = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        vnew = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if use_rope:
            knew = apply_rope(knew, q_pos, a.rope_theta)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, knew.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, vnew.astype(cache_v.dtype), (0, pos, 0, 0))
        k, v = cache_k, cache_v
        k_pos = jnp.arange(S, dtype=jnp.int32)
        kv_valid = None
        causal = True
    window = a.window if a.window else None
    if sharded_cache_chunks and not cross:
        out = decode_attention_sharded(
            q, k, v, pos, scale=1.0 / math.sqrt(hd), window=window,
            is_global=is_global, cap=a.logit_softcap,
            n_chunks=sharded_cache_chunks)
    else:
        out = _block_attend(q, k, v, q_pos, k_pos, scale=1.0 / math.sqrt(hd),
                            causal=causal, window=window, is_global=is_global,
                            cap=a.logit_softcap, kv_valid=kv_valid)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# --- MLA ------------------------------------------------------------------------

def _mla_q(p, x, a: AttentionConfig, positions, eps):
    if a.q_lora_rank:
        cq = x @ p["w_dq"].astype(x.dtype)
        cq = rmsnorm(cq, p["q_norm"], eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope = q[..., :a.qk_nope_dim]
    q_rope = apply_rope(q[..., a.qk_nope_dim:], positions, a.rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, a: AttentionConfig, *, positions, eps=1e-6):
    """Naive (materialized-K) MLA for train/prefill.

    Returns (out, (c_kv, k_rope)) for cache seeding."""
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, a, positions, eps)
    c_kv = rmsnorm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"], eps)
    k_rope = apply_rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :],
                        positions, a.rope_theta)        # (B,S,1,rd)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], a.qk_rope_dim))],
        axis=-1)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    out = blockwise_attention(q, k, v, positions, positions, scale=scale,
                              causal=True, cap=a.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(out, ("data", None, None)), (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, x, a: AttentionConfig, *, cache_ckv, cache_kr, pos, eps=1e-6):
    """Absorbed-latent decode. cache_ckv: (B,S,r); cache_kr: (B,S,rd)."""
    B, S, r = cache_ckv.shape
    q_pos = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, a, q_pos, eps)        # (B,1,H,*)
    c_new = rmsnorm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"], eps)
    kr_new = apply_rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :],
                        q_pos, a.rope_theta)[:, :, 0, :]
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_new.astype(cache_ckv.dtype), (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(
        cache_kr, kr_new.astype(cache_kr.dtype), (0, pos, 0))
    # absorb: q_latent[h] = q_nope[h] @ W_uk[h]^T  -> (B,1,H,r)
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, p["w_uk"].astype(x.dtype))
    scores = (jnp.einsum("bthr,bsr->bhts", q_lat.astype(jnp.float32),
                         cache_ckv.astype(jnp.float32))
              + jnp.einsum("bthk,bsk->bhts", q_rope.astype(jnp.float32),
                           cache_kr.astype(jnp.float32)))
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    scores = scores * scale
    k_pos = jnp.arange(S, dtype=jnp.int32)
    mask = (k_pos[None, :] <= pos)[None, None]          # (1,1,1,S) over (B,H,1,S)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhts,bsr->bthr", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bthr,rhv->bthv", out_lat.astype(x.dtype),
                     p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bthv,hvd->btd", out, p["wo"].astype(x.dtype))
    return out, cache_ckv, cache_kr
