"""Batched, device-resident P2 instances (DESIGN.md §10).

``BatchedProblem`` holds B independent P2 instances — one per (cell, round)
pair of a fleet — as stacked ``(B, U)`` arrays registered as a jax pytree:
the dynamic leaves are the channels, weights, per-worker power budgets
(paper eq. 10 is P_i^Max, a per-worker quantity) and noise variances; the
shape-defining analysis constants (D, S, κ, ``AnalysisConstants``) are
static aux data, so jitted solvers retrace only when shapes or constants
change, never on fresh channel draws (tests/test_sched.py recompile guard).

``rt`` / ``optimal_bt`` are the jnp ports of the reference's R_t (eq. 24)
and closed-form power scaler: they reduce over the **last** axis only, so
they evaluate batched ``(B, U)`` inputs directly and stay vmappable over
any leading axes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.theory.bounds import AnalysisConstants
from repro.kernels.prefix_eval import prefix_rt
from repro.sched.reference import Problem


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class BatchedProblem:
    """B stacked P2 instances; all per-worker arrays are (B, U)."""
    h: jnp.ndarray            # (B, U) channel magnitudes
    k_weights: jnp.ndarray    # (B, U) K_i
    p_max: jnp.ndarray        # (B, U) per-worker P_i^Max (eq. 10)
    noise_var: jnp.ndarray    # (B,) σ² per instance
    D: int
    S: int
    kappa: int
    const: AnalysisConstants

    # -- pytree protocol: arrays are leaves, problem constants are static --
    def tree_flatten(self):
        return ((self.h, self.k_weights, self.p_max, self.noise_var),
                (self.D, self.S, self.kappa, self.const))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        h, k_weights, p_max, noise_var = leaves
        D, S, kappa, const = aux
        return cls(h=h, k_weights=k_weights, p_max=p_max,
                   noise_var=noise_var, D=D, S=S, kappa=kappa, const=const)

    @property
    def B(self) -> int:
        return self.h.shape[0]

    @property
    def U(self) -> int:
        return self.h.shape[-1]

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_arrays(cls, h, k_weights, p_max, noise_var, *, D: int, S: int,
                    kappa: int, const: AnalysisConstants,
                    dtype=jnp.float32) -> "BatchedProblem":
        """Normalise broadcastable inputs: ``h`` fixes (B, U); ``k_weights``
        and ``p_max`` accept scalars / (U,) / (B, U); ``noise_var`` accepts
        a scalar or (B,)."""
        h = jnp.atleast_2d(jnp.asarray(h, dtype))
        B, U = h.shape
        k = jnp.broadcast_to(jnp.asarray(k_weights, dtype), (B, U))
        p = jnp.broadcast_to(jnp.asarray(p_max, dtype), (B, U))
        nv = jnp.broadcast_to(jnp.asarray(noise_var, dtype), (B,))
        return cls(h=h, k_weights=k, p_max=p, noise_var=nv, D=int(D),
                   S=int(S), kappa=int(kappa), const=const)

    @classmethod
    def from_problems(cls, problems: Sequence[Problem],
                      dtype=jnp.float32) -> "BatchedProblem":
        """Stack NumPy reference instances (shared D/S/κ/constants)."""
        p0 = problems[0]
        for p in problems[1:]:
            if (p.D, p.S, p.kappa, p.const) != (p0.D, p0.S, p0.kappa,
                                                p0.const):
                raise ValueError("from_problems requires shared "
                                 "D/S/kappa/const across instances")
        return cls.from_arrays(
            np.stack([p.h for p in problems]),
            np.stack([p.k_weights for p in problems]),
            np.stack([p.p_max_vec for p in problems]),
            np.asarray([p.noise_var for p in problems]),
            D=p0.D, S=p0.S, kappa=p0.kappa, const=p0.const, dtype=dtype)

    @classmethod
    def single(cls, prob: Problem, dtype=jnp.float32) -> "BatchedProblem":
        """Lift one reference instance to B = 1."""
        return cls.from_problems([prob], dtype=dtype)

    def instance(self, b: int) -> Problem:
        """Extract instance ``b`` back to a NumPy reference Problem."""
        return Problem(h=np.asarray(self.h[b], np.float64),
                       k_weights=np.asarray(self.k_weights[b], np.float64),
                       p_max=np.asarray(self.p_max[b], np.float64),
                       noise_var=float(self.noise_var[b]), D=self.D,
                       S=self.S, kappa=self.kappa, const=self.const)

    # -- P2 quantities (last-axis reductions; batched and vmappable) -------
    def caps(self) -> jnp.ndarray:
        """Per-worker b_t ceiling h_i √(P_i^Max) / K_i (eq. 11)."""
        return self.h * jnp.sqrt(self.p_max) / self.k_weights

    def optimal_bt(self, beta: jnp.ndarray) -> jnp.ndarray:
        """R_t strictly decreases in b_t ⇒ b_t* = min scheduled cap;
        0 where nothing is scheduled (matches the reference)."""
        sel = beta > 0
        b = jnp.min(jnp.where(sel, self.caps(), jnp.inf), axis=-1)
        return jnp.where(jnp.any(sel, axis=-1), b, 0.0)

    def rt(self, beta: jnp.ndarray, b_t: jnp.ndarray) -> jnp.ndarray:
        """Eq. (24) objective R_t per instance; +inf on empty schedules."""
        c = self.const
        K = jnp.sum(self.k_weights, axis=-1)
        denom = jnp.sum(self.k_weights * beta, axis=-1) * b_t
        safe = jnp.where(denom > 0, denom, 1.0)
        C2 = c.C ** 2
        r = jnp.sum(self.k_weights * c.rho1 * (1.0 - beta), axis=-1) / K
        r += C2 * (1.0 + (1.0 + c.delta) * (self.D - self.kappa)
                   / (self.S * self.D) * c.G ** 2
                   + self.noise_var / safe ** 2)
        r += jnp.sum(beta, axis=-1) * (1.0 + c.delta) \
            * (self.D - self.kappa) / self.D * c.G ** 2
        return jnp.where(denom > 0, r, jnp.inf)

    def rt_coefs(self):
        """Sufficient-statistic coefficients of R_t (DESIGN.md §10):
        R(s1, s2, b) = ρ1(Ktot − s2)/Ktot + A + N/(s2·b)² + s1·E.
        Returns per-instance (Ktot (B,), rho1, A, E, N (B,))."""
        c = self.const
        C2 = c.C ** 2
        ktot = jnp.sum(self.k_weights, axis=-1)
        A = C2 * (1.0 + (1.0 + c.delta) * (self.D - self.kappa)
                  / (self.S * self.D) * c.G ** 2)
        E = (1.0 + c.delta) * (self.D - self.kappa) / self.D * c.G ** 2
        return ktot, c.rho1, A, E, C2 * self.noise_var


def rt_from_stats(s1, s2, b, *, ktot, rho1, A, E, N):
    """R_t from the sufficient statistics — the *same* formula object the
    Pallas prefix kernel evaluates (identical op order keeps kernel/jnp
    parity bit-for-bit, DESIGN.md §10)."""
    return prefix_rt(s1, s2, b, ktot=ktot, rho1=rho1, A=A, E=E, N=N)
