"""Time-correlated fading scenario generator for fleet-scale scheduling.

Produces ``(rounds, cells, U)`` channel-magnitude trajectories to feed the
batched P2 solvers (DESIGN.md §10): each round is B = cells independent
instances, each cell a parameter server with U workers. Extends the
paper's i.i.d. block-fading §V setup (``core/channel.py``) along the axes
the related work needs — temporal correlation (Fan et al. 2021, joint
optimization over coupled rounds, arXiv:2104.03490) and realistic power
control under fading (Liu et al. 2023, error-feedback one-bit OTA,
arXiv:2303.11319):

- **Small-scale fading**: first-order Gauss-Markov on the complex fade,
  g_t = ρ g_{t−1} + √(1−ρ²) w_t with w ~ CN(0, 1), stationary at CN(0, 1)
  so magnitudes keep the Rayleigh marginal (E|g|² = 1) with autocorrelation
  E[g_t g*_{t+ℓ}] = ρ^ℓ. ``model="jakes"`` derives ρ = J₀(2π f_d T_s) from
  the Doppler spread (Jakes block-fading equivalence); ``model="iid"``
  (ρ = 0) recovers the paper's per-round redraw.
- **Large-scale gain**: static per (cell, worker) — log-normal shadowing
  (σ dB) and single-cell disk layouts with distance path loss — scaling
  the per-worker amplitude, i.e. a per-worker power budget once pushed
  through eq. 10's P_i^Max.

Everything is jax: PRNG-keyed, jit-able, so trajectory generation lives
on device next to the solvers it feeds. The fade process is exposed two
ways around one transition kernel: ``init_fades``/``step_fades`` advance
a ``FadeState`` one round at a time (the continuous scheduling service
ingests channel updates tick by tick, DESIGN.md §15), and
``generate_fades`` is literally that step's jitted executable chained —
so a stepped trajectory is bitwise-equal to the whole-trajectory draw at
every round (pinned by tests/test_serve.py). The step keys come from
``fold_in(key, t)``, making round t's draw a pure function of (state,
t) with no key-splitting chain to replay.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.channel import H_MIN, draw_cn, gauss_markov_step
from repro.theory.bounds import AnalysisConstants
from repro.sched.problem import BatchedProblem


def bessel_j0(x: float) -> float:
    """J₀ for the Jakes correlation coefficient (host-side scalar;
    Abramowitz & Stegun 9.4.1 / 9.4.3, |err| < 2e-7)."""
    ax = abs(x)
    if ax <= 3.0:
        y = (ax / 3.0) ** 2
        return (1.0 + y * (-2.2499997 + y * (1.2656208 + y * (-0.3163866
                + y * (0.0444479 + y * (-0.0039444 + y * 0.0002100))))))
    z = 3.0 / ax
    f0 = (0.79788456 + z * (-0.00000077 + z * (-0.00552740 + z * (
        -0.00009512 + z * (0.00137237 + z * (-0.00072805
                                             + z * 0.00014476))))))
    t0 = (ax - 0.78539816 + z * (-0.04166397 + z * (-0.00003954 + z * (
        0.00262573 + z * (-0.00054125 + z * (-0.00029333
                                             + z * 0.00013558))))))
    return f0 * math.cos(t0) / math.sqrt(ax)


@dataclass(frozen=True)
class ScenarioConfig:
    """A fleet of ``cells`` cells × ``workers`` workers over ``rounds``
    temporally correlated fading rounds."""
    rounds: int = 100
    cells: int = 16
    workers: int = 64
    model: str = "gauss_markov"   # gauss_markov | jakes | iid
    corr: float = 0.9             # ρ (gauss_markov)
    doppler_hz: float = 10.0      # f_d (jakes)
    slot_s: float = 0.01          # round duration T_s (jakes)
    shadowing_db: float = 0.0     # log-normal shadowing σ (dB); 0 = off
    cell_radius: float = 0.0      # disk layout radius; 0 = unit gain
    ref_dist: float = 0.05        # path-loss reference distance
    pathloss_exp: float = 3.7     # path-loss exponent α
    h_min: float = H_MIN          # clamp (channel-inversion boundedness)

    @property
    def rho(self) -> float:
        if self.model == "gauss_markov":
            return float(self.corr)
        if self.model == "jakes":
            return bessel_j0(2.0 * math.pi * self.doppler_hz * self.slot_s)
        if self.model == "iid":
            return 0.0
        raise ValueError(f"unknown fading model {self.model!r} "
                         "(gauss_markov|jakes|iid)")


class FadeState(NamedTuple):
    """The incremental fade process: current complex fades ``g``
    ((cells, U) complex64), the base PRNG key of the innovation stream,
    and the index ``t`` of the round ``g`` belongs to. Advance with
    ``step_fades``; the state is a scan carry (fixed structure/shape),
    so the serve loop and the trajectory generator share it as-is."""
    g: jnp.ndarray
    key: jnp.ndarray
    t: jnp.ndarray            # i32 round index of g


def init_fades(cfg: ScenarioConfig, key) -> FadeState:
    """Round-0 fade state: one stationary CN(0, 1) draw per
    (cell, worker), plus the innovation key for the steps to come."""
    k0, kw = jax.random.split(key)
    g0 = draw_cn(k0, (cfg.cells, cfg.workers)).astype(jnp.complex64)
    return FadeState(g=g0, key=kw, t=jnp.int32(0))


@functools.partial(jax.jit, static_argnums=0)
def _step_fades_jit(cfg: ScenarioConfig, state: FadeState) -> FadeState:
    k = jax.random.fold_in(state.key, state.t)
    g = gauss_markov_step(state.g, k, jnp.float32(cfg.rho))
    return FadeState(g=g.astype(jnp.complex64), key=state.key,
                     t=state.t + 1)


def step_fades(cfg: ScenarioConfig, state: FadeState) -> FadeState:
    """One Gauss-Markov round: g_{t+1} = ρ g_t + √(1−ρ²) w — see
    ``core/channel.py`` — with the innovation keyed ``fold_in(key, t)``
    so step t is a pure function of the state, no trajectory-length key
    split to precompute. Always runs the one cached jitted executable:
    ``generate_fades`` chains the very same executable, which is what
    makes stepped and whole-trajectory draws bitwise-equal (XLA may
    compile the same arithmetic to different fusions in different
    surrounding programs, so sharing the formula is not enough — the
    parity contract pins the compiled artifact)."""
    return _step_fades_jit(cfg, state)


def magnitudes(state_or_g, gain: Optional[jnp.ndarray] = None,
               h_min: float = H_MIN) -> jnp.ndarray:
    """Channel magnitudes |h| f32 from a ``FadeState`` (or raw complex
    fades), scaled by the static large-scale ``gain`` and clamped to
    ``h_min`` (bounded channel inversion, core/channel.py)."""
    g = state_or_g.g if isinstance(state_or_g, FadeState) else state_or_g
    h = jnp.abs(g)
    if gain is not None:
        h = h * gain
    return jnp.maximum(h.astype(jnp.float32), h_min)


def generate_fades(cfg: ScenarioConfig, key) -> jnp.ndarray:
    """Complex small-scale fades, (rounds, cells, U) complex64; stationary
    CN(0, 1) marginal, lag-ℓ autocorrelation ρ^ℓ. The draw and the
    recursion are ``core/channel.py``'s ``draw_cn``/``gauss_markov_step``
    — the same fade model the FL engine steps round by round
    (DESIGN.md §11). This chains the ``step_fades`` executable round by
    round, so host code stepping a ``FadeState`` itself reproduces the
    trajectory bitwise at every round (see the ``step_fades`` docstring
    for why the executable, not just the formula, is shared)."""
    st = init_fades(cfg, key)
    gs = [st.g]
    for _ in range(cfg.rounds - 1):
        st = step_fades(cfg, st)
        gs.append(st.g)
    return jnp.stack(gs, axis=0)


def large_scale_gain(cfg: ScenarioConfig, key) -> jnp.ndarray:
    """Static per-(cell, worker) amplitude gain: log-normal shadowing ×
    disk-layout path loss, (cells, U) f32; all-ones when both are off."""
    ks, kp = jax.random.split(key)
    shape = (cfg.cells, cfg.workers)
    gain = jnp.ones(shape, jnp.float32)
    if cfg.shadowing_db > 0:
        db = cfg.shadowing_db * jax.random.normal(ks, shape)
        gain = gain * 10.0 ** (db / 20.0)
    if cfg.cell_radius > 0:
        # uniform-in-disk distance, clamped to the reference distance
        d = cfg.cell_radius * jnp.sqrt(jax.random.uniform(kp, shape))
        d = jnp.maximum(d, cfg.ref_dist)
        gain = gain * (d / cfg.ref_dist) ** (-cfg.pathloss_exp / 2.0)
    return gain


def generate(cfg: ScenarioConfig, key) -> jnp.ndarray:
    """Channel-magnitude trajectories |h|, (rounds, cells, U) f32,
    clamped to ``h_min`` (bounded channel inversion, core/channel.py)."""
    kf, kg = jax.random.split(key)
    h = jnp.abs(generate_fades(cfg, kf)) * large_scale_gain(cfg, kg)[None]
    return jnp.maximum(h.astype(jnp.float32), cfg.h_min)


def round_problems(traj: jnp.ndarray, t, *, k_weights, p_max, noise_var,
                   D: int, S: int, kappa: int,
                   const: AnalysisConstants) -> BatchedProblem:
    """Slice round ``t`` of a (rounds, cells, U) trajectory into a
    B = cells ``BatchedProblem`` for the batched solvers."""
    h = traj[t]                                          # (cells, U)
    return BatchedProblem.from_arrays(h, k_weights, p_max, noise_var,
                                      D=D, S=S, kappa=kappa, const=const)
