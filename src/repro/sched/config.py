"""Solver knobs shared by the batched P2 schedulers (DESIGN.md §10).

Frozen + hashable so a ``SchedConfig`` rides as a jit static argument —
changing a knob recompiles, changing channels never does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class SchedConfig:
    """ADMM (Algorithm 2) + flip-polish + prefix-sweep configuration.

    The defaults mirror the NumPy reference (``repro.sched.reference``)
    except ``inner_iters``: the step-1 projected gradient steps with
    1/Lipschitz, which jumps to the local quadratic minimizer each
    iteration, so the r-subproblem reaches its float32 fixed point in
    ≲12 steps — 16 and the reference's 50 produce bit-identical β
    schedules (tests/test_sched.py); the float64 oracle keeps 50 for
    headroom."""
    c_step: float = 1.0          # ADMM penalty c
    max_iters: int = 200         # outer ADMM iterations (upper bound)
    inner_iters: int = 16        # step-1 projected-gradient iterations
    abs_tol: float = 1e-4        # primal residual Σ|q−b| tolerance
    rel_tol: float = 1e-5        # b_t drift tolerance
    polish_sweeps: int = 3       # flip-polish sweep cap
    # greedy prefix sweep: route the (B, U) evaluation through the Pallas
    # kernel (kernels/prefix_eval.py) instead of the jnp cumsum path
    use_kernel: bool = False
    interpret: Optional[bool] = None      # None -> auto (True off-TPU)
    kernel_tiles: Optional[Tuple[int, int]] = None  # (bb, bu) override
