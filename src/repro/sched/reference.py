"""NumPy reference for P2 — joint worker-scheduling + power-scaling (§IV).

P2:  min_{b_t, β_t} R_t   s.t.  β_i² K_i² b_t² / h_i² ≤ P_i^Max, β ∈ {0,1}^U.

Moved here from ``repro.core.scheduling`` (shim since retired) when the
batched device-resident solvers landed in ``repro.sched`` (DESIGN.md §10).
This module stays the **parity oracle**: scalar, float64, one instance per
call — ``repro.sched.admm.admm_solve_batched`` and
``repro.sched.greedy.greedy_solve_batched`` are tested against it instance
by instance (tests/test_sched.py).

Three solvers, as in the paper plus one beyond-paper baseline:
- Algorithm 1 (``enumerate_solve``): exact — enumerate 2^U − 1 schedules;
  for fixed β the optimal b_t is closed-form (R_t is strictly decreasing in
  b_t, so b_t* sits on the tightest power boundary).
- Algorithm 2 (``admm_solve``): O(U) ADMM on the P3 reformulation with
  auxiliaries r_i = β_i q_i, q_i = b_t and multipliers (ν, ξ, ς), followed
  by an O(U)-per-sweep flip-polish (incremental Δ-evaluation of R_t).
- ``greedy_solve``: prefix search over the channel-cap order — exact for
  equal K_i.

The power budget is per-worker (paper eq. 10 is P_i^Max): ``Problem.p_max``
accepts a (U,) array; a scalar broadcasts to all workers (the paper's §V
setup).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Tuple, Union

import numpy as np

from repro.theory.bounds import AnalysisConstants

# Stall cut shared with the batched solver (repro.sched.admm): stop when
# the primal residual has not improved by STALL_RTOL (relative) for
# STALL_PATIENCE consecutive iterations — float64 rarely triggers it, but
# the float32 device path needs it to retire oscillating instances, and
# the two implementations must share one convergence rule.
STALL_RTOL = 1e-3
STALL_PATIENCE = 10


@dataclass(frozen=True)
class Problem:
    """One round's P2 instance."""
    h: np.ndarray                        # (U,) channel magnitudes
    k_weights: np.ndarray                # (U,) K_i
    p_max: Union[float, np.ndarray]      # P_i^Max: scalar broadcast or (U,)
    noise_var: float                     # σ²
    D: int
    S: int
    kappa: int
    const: AnalysisConstants

    @property
    def U(self) -> int:
        return len(self.h)

    @property
    def p_max_vec(self) -> np.ndarray:
        """Per-worker P_i^Max (eq. 10); scalars broadcast to (U,)."""
        return np.broadcast_to(np.asarray(self.p_max, np.float64),
                               (self.U,))

    def caps(self) -> np.ndarray:
        """Per-worker b_t ceiling h_i √(P_i^Max) / K_i (eq. 11)."""
        return self.h * np.sqrt(self.p_max_vec) / self.k_weights


def _rt(prob: Problem, beta: np.ndarray, b_t: float) -> float:
    c = prob.const
    K = prob.k_weights.sum()
    denom = float((prob.k_weights * beta).sum()) * b_t
    if denom <= 0:
        return np.inf
    C2 = c.C ** 2
    r = (prob.k_weights * c.rho1 * (1.0 - beta)).sum() / K
    r += C2 * (1.0 + (1.0 + c.delta) * (prob.D - prob.kappa)
               / (prob.S * prob.D) * c.G ** 2
               + prob.noise_var / denom ** 2)
    r += beta.sum() * (1.0 + c.delta) * (prob.D - prob.kappa) / prob.D \
        * c.G ** 2
    return float(r)


def _rt_coefs(prob: Problem):
    """Sufficient-statistic form of R_t (DESIGN.md §10): R_t depends on β
    only through s1 = Σβ, s2 = ΣK_iβ_i and the min-cap b, as

        R(s1, s2, b) = ρ1 (Ktot − s2)/Ktot + A + N/(s2·b)² + s1·E

    with A the schedule-independent floor, N = C²σ², E the per-scheduled
    sparsification penalty. Returns (Ktot, rho1, A, E, N)."""
    c = prob.const
    C2 = c.C ** 2
    ktot = float(prob.k_weights.sum())
    A = C2 * (1.0 + (1.0 + c.delta) * (prob.D - prob.kappa)
              / (prob.S * prob.D) * c.G ** 2)
    E = (1.0 + c.delta) * (prob.D - prob.kappa) / prob.D * c.G ** 2
    return ktot, float(c.rho1), A, E, C2 * prob.noise_var


def _rt_from_stats(coefs, s1: float, s2: float, b: float) -> float:
    ktot, rho1, A, E, N = coefs
    if s2 <= 0 or b <= 0:
        return np.inf
    return rho1 * (ktot - s2) / ktot + A + N / (s2 * b) ** 2 + s1 * E


def optimal_bt(prob: Problem, beta: np.ndarray) -> float:
    """R_t strictly decreases in b_t ⇒ b_t* = min_i scheduled cap_i."""
    sel = beta > 0
    if not sel.any():
        return 0.0
    return float(prob.caps()[sel].min())


def enumerate_solve(prob: Problem) -> Tuple[np.ndarray, float, float]:
    """Algorithm 1. Returns (β*, b_t*, R_t*). O(2^U) — small U only."""
    U = prob.U
    best = (None, 0.0, np.inf)
    for bits in itertools.product((0, 1), repeat=U):
        beta = np.asarray(bits, np.float64)
        if beta.sum() == 0:
            continue
        b = optimal_bt(prob, beta)
        r = _rt(prob, beta, b)
        if r < best[2]:
            best = (beta, b, r)
    return best


def _step1_rb(prob: Problem, q, beta, nu, xi, zeta, b_prev, c_step,
              inner_iters=50):
    """Minimize L wrt (r, b): projected gradient on r (smooth convex) with
    per-coordinate curvature steps, closed form for b."""
    c2s2 = prob.const.C ** 2 * prob.noise_var
    K = prob.k_weights
    r = np.maximum(beta * q, 1e-8)
    # per-coordinate Lipschitz of the quadratic parts
    lip = 2.0 * nu * K ** 2 / prob.h ** 2 + c_step + 1e-6
    for _ in range(inner_iters):
        denom = max(float((K * r).sum()), 1e-9)
        gQ1 = -2.0 * c2s2 / denom ** 3 * K
        gpen = nu * 2.0 * K ** 2 * r / prob.h ** 2
        glin = xi + c_step * (r - beta * q)
        g = gQ1 + gpen + glin
        r = np.maximum(r - g / lip, 1e-9)
    b = float(np.mean(q) + np.mean(zeta) / c_step)
    b = max(b, 1e-9)
    return r, b


def _step2_qbeta(prob: Problem, r, b, nu, xi, zeta, c_step):
    """Per-worker closed forms for q under β=0 / β=1, pick the smaller
    objective (eq. 34-36)."""
    c = prob.const
    K = prob.k_weights
    Ksum = K.sum()
    # beta = 0: q = b - zeta/c
    q0 = np.maximum(b - zeta / c_step, 1e-9)
    obj0 = (K * c.rho1 / Ksum
            + xi * r + 0.5 * c_step * r ** 2
            + zeta * (q0 - b) + 0.5 * c_step * (q0 - b) ** 2)
    # beta = 1: q = (xi - zeta + c r + c b) / (2c)
    q1 = np.maximum((xi - zeta + c_step * (r + b)) / (2.0 * c_step), 1e-9)
    obj1 = ((1.0 + c.delta) * (prob.D - prob.kappa) / prob.D * c.G ** 2
            + xi * (r - q1) + 0.5 * c_step * (r - q1) ** 2
            + zeta * (q1 - b) + 0.5 * c_step * (q1 - b) ** 2)
    beta = (obj1 < obj0).astype(np.float64)
    q = np.where(beta > 0, q1, q0)
    return q, beta


def greedy_prefix_bound(prob: Problem) -> float:
    """Best prefix R_t over the channel-cap order (the ``greedy_solve``
    optimum), in O(U log U) via the sufficient-statistic form — the
    flip-polish early-exit bound (DESIGN.md §10)."""
    caps = prob.caps()
    order = np.argsort(-caps)
    ks = prob.k_weights[order]
    coefs = _rt_coefs(prob)
    ktot, rho1, A, E, N = coefs
    s2 = np.cumsum(ks)
    s1 = np.arange(1, prob.U + 1, dtype=np.float64)
    b = caps[order]
    r = rho1 * (ktot - s2) / ktot + A + N / (s2 * b) ** 2 + s1 * E
    return float(r.min())


def _flip_polish(prob: Problem, beta: np.ndarray, *, max_sweeps: int = 3
                 ) -> np.ndarray:
    """First-improvement flip local search on β, O(U) per sweep via
    incremental Δ-evaluation: each candidate R_t comes from the sufficient
    statistics (s1, s2, min-cap) in O(1) — the min-cap after dropping the
    boundary worker is the second-smallest scheduled cap, so only an
    *accepted* flip recomputes the O(U) min statistics."""
    caps = prob.caps()
    K = prob.k_weights
    coefs = _rt_coefs(prob)
    U = prob.U
    s1 = float(beta.sum())
    s2 = float((K * beta).sum())

    def min_stats():
        sel_caps = np.where(beta > 0, caps, np.inf)
        i1 = int(np.argmin(sel_caps))
        m1 = float(sel_caps[i1])
        sel_caps = sel_caps.copy()
        sel_caps[i1] = np.inf
        return i1, m1, float(sel_caps.min())

    i1, m1, m2 = min_stats()
    best_r = _rt_from_stats(coefs, s1, s2, m1)
    for _ in range(max_sweeps):
        improved = False
        for i in range(U):
            if beta[i] > 0:
                if s1 <= 1:
                    continue
                b_c = m2 if i == i1 else m1
                r_c = _rt_from_stats(coefs, s1 - 1.0, s2 - K[i], b_c)
            else:
                r_c = _rt_from_stats(coefs, s1 + 1.0, s2 + K[i],
                                     min(m1, caps[i]))
            if r_c < best_r - 1e-12:
                beta[i] = 1.0 - beta[i]
                s1 += 1.0 if beta[i] > 0 else -1.0
                s2 += K[i] if beta[i] > 0 else -K[i]
                i1, m1, m2 = min_stats()
                best_r = r_c
                improved = True
        if not improved:
            break
    return beta


def admm_solve(prob: Problem, *, c_step: float = 1.0, max_iters: int = 200,
               abs_tol: float = 1e-4,
               rel_tol: float = 1e-5) -> Tuple[np.ndarray, float, float]:
    """Algorithm 2. Returns (β*, b_t*, R_t*). O(U) per iteration."""
    U = prob.U
    p_max = prob.p_max_vec
    beta = np.ones(U)
    b = max(optimal_bt(prob, beta), 1e-6)   # feasible warm start
    q = np.full(U, b)
    nu = np.zeros(U)
    xi = np.zeros(U)
    zeta = np.zeros(U)
    prim_best, stall = np.inf, 0
    for it in range(max_iters):
        r, b_new = _step1_rb(prob, q, beta, nu, xi, zeta, b, c_step)
        q, beta = _step2_qbeta(prob, r, b_new, nu, xi, zeta, c_step)
        # Step 3: multiplier updates (37)-(39); ν projected to >= 0
        nu = np.maximum(
            nu + c_step * ((prob.k_weights * r / prob.h) ** 2 - p_max),
            0.0)
        xi = xi + c_step * (r - beta * q)
        zeta = zeta + c_step * (q - b_new)
        prim = float(np.abs(q - b_new).sum())
        drift = abs(b_new - b)
        b = b_new
        stall = 0 if prim < prim_best * (1.0 - STALL_RTOL) else stall + 1
        prim_best = min(prim_best, prim)
        if it > 5 and ((prim < abs_tol and drift < rel_tol)
                       or stall >= STALL_PATIENCE):
            break
    # project: final β from ADMM, b_t from the exact power boundary
    if beta.sum() == 0:
        beta[int(np.argmax(prob.caps()))] = 1.0
    # flip-polish (engineering refinement over the paper's raw ADMM output;
    # keeps the solver polynomial, DESIGN.md §10). Early-exit: when the
    # ADMM point already matches the greedy prefix bound (relative
    # tolerance — both sides evaluated through the same sufficient-stats
    # arithmetic), local flips cannot improve a prefix-family optimum.
    coefs = _rt_coefs(prob)
    r_admm = _rt_from_stats(coefs, float(beta.sum()),
                            float((prob.k_weights * beta).sum()),
                            optimal_bt(prob, beta))
    if r_admm > greedy_prefix_bound(prob) * (1.0 + 1e-6):
        beta = _flip_polish(prob, beta)
    b_final = optimal_bt(prob, beta)
    return beta, b_final, _rt(prob, beta, b_final)


def greedy_solve(prob: Problem) -> Tuple[np.ndarray, float, float]:
    """Beyond-paper baseline: sort workers by channel quality cap
    h_i √(P_i^Max)/K_i (descending); evaluate the U prefix schedules; pick
    best. O(U log U) and, because R_t depends on β only through Σβ, ΣK_iβ
    and the min-cap, the optimum is always a prefix of this ordering when
    K_i are equal — making it exact for the paper's §V setup. The loop form
    here is the oracle for the vectorized/Pallas prefix sweep
    (``repro.sched.greedy``, DESIGN.md §10)."""
    caps = prob.caps()
    order = np.argsort(-caps)
    best = (None, 0.0, np.inf)
    beta = np.zeros(prob.U)
    for i in order:
        beta[i] = 1.0
        b = optimal_bt(prob, beta)
        r = _rt(prob, beta, b)
        if r < best[2]:
            best = (beta.copy(), b, r)
    return best
