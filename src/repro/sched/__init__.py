"""repro.sched — batched, device-resident P2 scheduling (paper §IV).

The joint worker-scheduling + power-scaling optimization as a registry of
interchangeable solvers behind one entry point (``schedule``), with the
fleet path batched over B independent instances: ``BatchedProblem`` stacks
(cell, round) P2 instances as pytree leaves, ``admm_solve_batched`` runs
Algorithm 2 vmapped in one device call, ``greedy_solve_batched`` collapses
the prefix search to sort + cumsum + argmin with a Pallas sweep kernel at
large U, and ``scenario`` generates the time-correlated fading
trajectories that feed them. See DESIGN.md §10.

Layering: this package imports ``repro.kernels`` and the analysis layer
``repro.theory`` (DESIGN.md §12) only; ``repro.core``, ``repro.engine``
and ``repro.fl`` consume it (``repro.sched.reference`` is the NumPy
parity oracle the batched solvers are tested against).
"""
from repro.sched.admm import (AdmmDuals, AdmmSolveInfo, admm_solve_batched,
                              admm_solve_batched_jit)
from repro.sched.compaction import MIN_BUCKET, bucket, pad_to_bucket, take
from repro.sched.config import SchedConfig
from repro.sched.greedy import greedy_solve_batched, prefix_sweep
from repro.sched.problem import BatchedProblem, rt_from_stats
from repro.sched.reference import (Problem, admm_solve, enumerate_solve,
                                   greedy_prefix_bound, greedy_solve,
                                   optimal_bt)
from repro.sched.registry import (Scheduler, get_scheduler, list_schedulers,
                                  register_scheduler, schedule)
from repro.sched.scenario import (FadeState, ScenarioConfig, generate,
                                  generate_fades, init_fades, magnitudes,
                                  round_problems, step_fades)

__all__ = [
    "AdmmDuals", "AdmmSolveInfo", "BatchedProblem", "FadeState", "MIN_BUCKET",
    "Problem", "ScenarioConfig", "SchedConfig",
    "Scheduler", "admm_solve", "admm_solve_batched",
    "admm_solve_batched_jit", "bucket", "enumerate_solve",
    "generate", "generate_fades", "get_scheduler", "greedy_prefix_bound",
    "greedy_solve", "greedy_solve_batched", "init_fades", "list_schedulers",
    "magnitudes", "optimal_bt", "pad_to_bucket",
    "prefix_sweep", "register_scheduler", "round_problems", "rt_from_stats",
    "schedule", "step_fades", "take",
]
