"""Algorithm 2 (ADMM for P3) as a batched, device-resident solver.

One jitted call schedules an entire fleet: the reference ADMM — step 1
projected gradient on r with a closed-form b, step 2 per-worker β/q closed
forms (eq. 34-36), step 3 multiplier updates (37)-(39) — runs as a jitted
``lax.scan`` over fixed-size iteration chunks with convergence masking
over B independent P2 instances (DESIGN.md §10). The update is written
with last-axis reductions (vmap semantics, hand-vectorized) so XLA fuses
the (B, U) hot loop into a handful of passes.

Faithfulness to the NumPy oracle (``repro.sched.reference.admm_solve``):

- Each scan chunk applies the reference update with a per-instance
  ``done`` mask replicating the scalar solver's convergence break
  (Σ|q−b| < abs_tol, |Δb| < rel_tol, it > 5) plus the shared stall cut
  (no relative primal improvement for ``STALL_PATIENCE`` iterations —
  also in the reference, where float64 almost never triggers it): a
  converged instance's state freezes at exactly the scalar break point.
- Between chunks a host-driven **compaction** loop gathers the still-active
  instances into the next power-of-two bucket, so a fleet pays for the
  convergence *distribution* (median ≈ 7 outer iterations), not for
  B × the worst straggler. Bucket shapes are bounded (log₂B jit entries).
- The flip-polish is the same first-improvement index-order local search,
  expressed as a ``lax.scan`` over sweeps × coordinates with candidate R_t
  evaluated from the sufficient statistics (Σβ, ΣK_iβ, min-cap) — no
  per-candidate rebuild — and run only on the instances whose ADMM point
  does not already match the greedy prefix bound (host-compacted; most of
  a fleet exits on the bound).

Per-instance parity with the float64 reference is tested at B ≥ 64
(tests/test_sched.py); the batched path runs float32 on-device, so parity
is tolerance-based, not bitwise.

Dual warm-starting (DESIGN.md §15): both solvers accept and return the
ADMM multipliers — ν (the eq. 37 power-constraint prices), ξ (eq. 38
r = βq coupling) and ζ (eq. 39 q = b consensus, the paper's λ) — as an
``AdmmDuals`` pytree. Seeding a solve with the duals of a nearby problem
(the previous service tick's channels, a Gauss-Markov-correlated fade
draw) starts the multipliers at prices that are already close to optimal,
so convergence takes fewer outer iterations; by default the primal state
re-initializes from the problem itself, so warm and cold solves converge
to the same β (the parity flag benchmarks/serve_bench.py gates). An
optional ``warm_beta`` also seeds the primal from a cached schedule
projected to a feasible point; measured on correlated fades it saves no
outer iterations over dual-only and gives up the bitwise cold-parity
guarantee (serve/warm-parity telemetry rows), so it stays off by
default everywhere.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sched.compaction import bucket as _bucket
from repro.sched.compaction import pad_to_bucket, take as _take
from repro.sched.config import SchedConfig
from repro.sched.problem import BatchedProblem, rt_from_stats
from repro.sched.reference import STALL_PATIENCE, STALL_RTOL

_DEFAULT = SchedConfig()
_CHUNK = 8          # outer iterations per jitted scan chunk


class AdmmDuals(NamedTuple):
    """The Algorithm-2 multiplier state, (B, U) f32 leaves: ν ≥ 0 prices
    the per-worker power constraints (eq. 37), ξ couples r = βq (eq. 38),
    ζ enforces the q = b consensus (eq. 39, the reference's λ)."""
    nu: jnp.ndarray
    xi: jnp.ndarray
    zeta: jnp.ndarray

    @classmethod
    def zeros(cls, shape, dtype=jnp.float32) -> "AdmmDuals":
        z = jnp.zeros(shape, dtype)
        return cls(nu=z, xi=z, zeta=z)


class AdmmSolveInfo(NamedTuple):
    """Per-lane solve telemetry returned with ``return_duals=True``:
    the exit multipliers (warm-start state for the next nearby solve)
    and the outer-iteration count each lane took to converge."""
    duals: AdmmDuals
    iters: jnp.ndarray          # (B,) i32 outer iterations at the break


def _bcast(flag, leaf):
    """Broadcast a (B,) lane mask against a (B, ...) state leaf."""
    return flag.reshape(flag.shape + (1,) * (leaf.ndim - flag.ndim))


def _greedy_prefix_bound(prob: BatchedProblem, caps) -> jnp.ndarray:
    """Best prefix R_t over the channel-cap order — the polish early-exit
    bound (DESIGN.md §10). Sort-free: worker i's prefix is
    {j : cap_j ≥ cap_i}, so the masked O(U²) count/mass (a tiny batched
    GEMM) replaces XLA CPU's slow per-row sort; on exact cap ties this
    evaluates the union prefix (measure-zero for continuous channels, and
    a too-high bound only makes one extra instance take the polish)."""
    ge = (caps[..., None, :] >= caps[..., :, None]).astype(caps.dtype)
    s1 = jnp.sum(ge, axis=-1)
    s2 = jnp.einsum("...ij,...j->...i", ge, prob.k_weights)
    ktot, rho1, A, E, N = prob.rt_coefs()
    r = rt_from_stats(s1, s2, caps, ktot=ktot[..., None], rho1=rho1,
                      A=A, E=E, N=N[..., None])
    return jnp.min(r, axis=-1)


# --- ADMM iteration (batched-native: leaves (B, U), lane scalars (B,)) -------------

def _init_state(prob: BatchedProblem, duals: Optional[AdmmDuals] = None,
                warm_beta: Optional[jnp.ndarray] = None):
    """Initial ADMM state; ``duals`` warm-starts the multipliers only —
    by default the primal (q, β, b) re-initializes from the problem, so
    a warm solve walks to the same fixed point from better prices.

    ``warm_beta`` additionally seeds the primal from a cached schedule,
    projected to a feasible point of P3: binarized to {0,1} with empty
    lanes falling back to the all-on cold init, b and q re-derived from
    the projected β via the eq. 16 closed form (a stale b would violate
    the q = b consensus from iteration 0). Primal warm starts move the
    ADMM trajectory, so the fixed point is NOT guaranteed bitwise-equal
    to cold-start — measured on correlated fades it saves no outer
    iterations over dual-only (serve/warm-parity telemetry), which is
    why the serve loop carries duals only."""
    caps = prob.caps()
    if warm_beta is None:
        beta0 = jnp.ones_like(caps)
    else:
        wb = (warm_beta.astype(caps.dtype) > 0.5).astype(caps.dtype)
        empty = jnp.sum(wb, axis=-1, keepdims=True) == 0
        beta0 = jnp.where(empty, jnp.ones_like(caps), wb)
    b0 = jnp.maximum(prob.optimal_bt(beta0), 1e-6)          # (B,)
    z = jnp.zeros_like(caps)
    nu, xi, zeta = (z, z, z) if duals is None else (
        duals.nu.astype(caps.dtype), duals.xi.astype(caps.dtype),
        duals.zeta.astype(caps.dtype))
    B = caps.shape[:-1]
    # (q, beta, b, nu, xi, zeta, done, it, prim_best, stall)
    return (b0[..., None] * jnp.ones_like(caps), beta0, b0, nu, xi, zeta,
            jnp.zeros(B, bool), jnp.zeros(B, jnp.int32),
            jnp.full(B, jnp.inf, jnp.float32), jnp.zeros(B, jnp.int32))


def _outer_iter(prob: BatchedProblem, cfg: SchedConfig, st):
    """One masked reference iteration: steps 1-3 + convergence/stall check.
    Loop invariants of the step-1 projected gradient are hoisted (the
    gradient is t(Σ K r)·K + (pen + c)·r + g₀ with pen, g₀ fixed within an
    outer iteration — same math as the reference, fewer arrays touched)."""
    q, beta, b, nu, xi, zeta, done, it, prim_best, stall = st
    c = prob.const
    cs = cfg.c_step
    h, K, p_max = prob.h, prob.k_weights, prob.p_max
    c2s2 = (c.C ** 2 * prob.noise_var)[..., None]           # (B, 1)

    # step 1: projected gradient on r, closed form for b
    penc = 2.0 * nu * K ** 2 / h ** 2 + cs                  # pen + c
    inv_lip = 1.0 / (penc + 1e-6)
    g0 = xi - cs * (beta * q)                   # loop-invariant linear part
    r0 = jnp.maximum(beta * q, 1e-8)

    def inner(_, r):
        denom = jnp.maximum(jnp.sum(K * r, axis=-1, keepdims=True), 1e-9)
        t = -2.0 * c2s2 / denom ** 3
        return jnp.maximum(r - (t * K + penc * r + g0) * inv_lip, 1e-9)

    r = jax.lax.fori_loop(0, cfg.inner_iters, inner, r0)
    b_new = jnp.maximum(jnp.mean(q, axis=-1)
                        + jnp.mean(zeta, axis=-1) / cs, 1e-9)   # (B,)
    bn = b_new[..., None]

    # step 2: per-worker closed forms for (q, β) (eq. 34-36)
    E_pen = (1.0 + c.delta) * (prob.D - prob.kappa) / prob.D * c.G ** 2
    Ksum = jnp.sum(K, axis=-1, keepdims=True)
    q0 = jnp.maximum(bn - zeta / cs, 1e-9)
    obj0 = (K * c.rho1 / Ksum + xi * r + 0.5 * cs * r ** 2
            + zeta * (q0 - bn) + 0.5 * cs * (q0 - bn) ** 2)
    q1 = jnp.maximum((xi - zeta + cs * (r + bn)) / (2.0 * cs), 1e-9)
    obj1 = (E_pen + xi * (r - q1) + 0.5 * cs * (r - q1) ** 2
            + zeta * (q1 - bn) + 0.5 * cs * (q1 - bn) ** 2)
    beta_n = (obj1 < obj0).astype(r.dtype)
    q_n = jnp.where(beta_n > 0, q1, q0)

    # step 3: multiplier updates (37)-(39); ν projected to ≥ 0
    nu_n = jnp.maximum(nu + cs * ((K * r / h) ** 2 - p_max), 0.0)
    xi_n = xi + cs * (r - beta_n * q_n)
    zeta_n = zeta + cs * (q_n - bn)

    prim = jnp.sum(jnp.abs(q_n - bn), axis=-1)              # (B,)
    drift = jnp.abs(b_new - b)
    improved = prim < prim_best * (1.0 - STALL_RTOL)
    stall_n = jnp.where(improved, 0, stall + 1)
    prim_best_n = jnp.minimum(prim_best, prim)
    done_n = (it > 5) & (((prim < cfg.abs_tol) & (drift < cfg.rel_tol))
                         | (stall_n >= STALL_PATIENCE))

    new = (q_n, beta_n, b_new, nu_n, xi_n, zeta_n, done_n, it + 1,
           prim_best_n, stall_n)
    # convergence masking: frozen lanes carry their break-point state
    frozen = done | (it >= cfg.max_iters)
    return jax.tree_util.tree_map(
        lambda old_l, new_l: jnp.where(_bcast(frozen, old_l), old_l, new_l),
        st, new)


@functools.partial(jax.jit, static_argnames="cfg")
def _init_batched(prob, cfg, duals=None, warm_beta=None):
    return _init_state(prob, duals, warm_beta)


@functools.partial(jax.jit, static_argnames="cfg")
def _chunk_batched(prob, cfg, st):
    def body(st, _):
        return _outer_iter(prob, cfg, st), ()

    st, _ = jax.lax.scan(body, st, None, length=_CHUNK)
    return st


# --- flip-polish + projection (batched) --------------------------------------------

@jax.jit
def _project_batched(prob, beta):
    """Empty-schedule fallback + greedy-prefix early exit (DESIGN.md §10):
    both sides of the exit test go through the same sufficient-stats
    arithmetic, so exact prefix optima compare equal up to the relative
    tolerance and skip the polish entirely."""
    caps = prob.caps()
    empty = jnp.sum(beta, axis=-1, keepdims=True) == 0
    fallback = (jax.lax.broadcasted_iota(jnp.int32, caps.shape,
                                         caps.ndim - 1)
                == jnp.argmax(caps, axis=-1, keepdims=True))
    beta = jnp.where(empty, fallback.astype(beta.dtype), beta)
    ktot, rho1, A, E, N = prob.rt_coefs()
    best0 = rt_from_stats(jnp.sum(beta, axis=-1),
                          jnp.sum(prob.k_weights * beta, axis=-1),
                          prob.optimal_bt(beta), ktot=ktot, rho1=rho1,
                          A=A, E=E, N=N)
    active = best0 > _greedy_prefix_bound(prob, caps) * (1.0 + 1e-6)
    return beta, best0, active


def _polish_one(prob: BatchedProblem, cfg: SchedConfig, beta, best0):
    """First-improvement index-order flip search, Δ-evaluated from the
    sufficient statistics (the reference's ``_flip_polish``)."""
    U = prob.U
    K = prob.k_weights
    caps = prob.caps()
    ktot, rho1, A, E, N = prob.rt_coefs()
    coefs = dict(ktot=ktot, rho1=rho1, A=A, E=E, N=N)

    def polish_step(carry, step):
        beta, best_r, improved, active = carry
        i = step % U
        # sweep boundary: stop if the previous sweep found nothing
        at_boundary = (i == 0) & (step > 0)
        active = active & jnp.where(at_boundary, improved, True)
        improved = jnp.where(at_boundary, False, improved)
        beta_c = beta.at[i].set(1.0 - beta[i])
        s1c = jnp.sum(beta_c)
        s2c = jnp.sum(K * beta_c)
        bc = jnp.min(jnp.where(beta_c > 0, caps, jnp.inf))
        r_c = rt_from_stats(s1c, s2c, bc, **coefs)
        accept = active & (s1c > 0) & (r_c < best_r - 1e-12)
        beta = jnp.where(accept, beta_c, beta)
        best_r = jnp.where(accept, r_c, best_r)
        return (beta, best_r, improved | accept, active), ()

    steps = jnp.arange(cfg.polish_sweeps * U, dtype=jnp.int32)
    (beta, _, _, _), _ = jax.lax.scan(
        polish_step, (beta, best0, jnp.asarray(False), jnp.asarray(True)),
        steps, unroll=4)
    return beta


@functools.partial(jax.jit, static_argnames="cfg")
def _polish_apply(prob, cfg, beta, best0, pad):
    """Gather the polish-active instances, flip-polish them, scatter the
    schedules back — one jit per bucket shape. ``pad`` may repeat its
    first entry to fill the bucket: duplicates polish identical inputs to
    identical outputs, so the scatter is collision-safe."""
    sub = _take(prob, pad)
    polished = jax.vmap(lambda p, b, r0: _polish_one(p, cfg, b, r0))(
        sub, beta[pad], best0[pad])
    return beta.at[pad].set(polished)


@jax.jit
def _results_batched(prob, beta):
    b_t = prob.optimal_bt(beta)
    return beta, b_t, prob.rt(beta, b_t)


@functools.partial(jax.jit, static_argnames=("cfg", "return_duals"))
def admm_solve_batched_jit(prob: BatchedProblem,
                           cfg: Optional[SchedConfig] = None,
                           duals: Optional[AdmmDuals] = None,
                           return_duals: bool = False,
                           warm_beta: Optional[jnp.ndarray] = None):
    """Fully device-resident Algorithm 2 — the scan-safe sibling of
    ``admm_solve_batched`` (callable inside ``lax.scan``/``vmap``, e.g.
    from the FL engine's round body, DESIGN.md §11).

    Same masked ``_outer_iter`` updates and flip-polish as the compacted
    solver, so per-lane results are bit-identical; the difference is
    purely orchestration: convergence is a ``lax.while_loop`` over scan
    chunks instead of the host compaction loop, and the polish runs
    vmapped over all lanes with the greedy-prefix early exit applied as a
    mask. Use the compacted entry for large fleets (it pays for the
    convergence distribution, not the straggler); use this one where the
    call must stay inside a jitted program.

    ``duals`` warm-starts the multipliers (the engine carries them round
    to round next to prev-β, DESIGN.md §15); ``warm_beta`` additionally
    seeds the primal from a cached schedule (see ``_init_state`` — moves
    the trajectory, so no bitwise-parity guarantee vs cold);
    ``return_duals=True`` also returns an ``AdmmSolveInfo`` with the
    exit duals + iteration counts."""
    cfg = cfg or _DEFAULT

    def chunk(st):
        def body(st, _):
            return _outer_iter(prob, cfg, st), ()

        st, _ = jax.lax.scan(body, st, None, length=_CHUNK)
        return st

    def not_done(st):
        return ~jnp.all(st[6] | (st[7] >= cfg.max_iters))

    st = jax.lax.while_loop(not_done, chunk,
                            _init_state(prob, duals, warm_beta))
    beta, best0, active = _project_batched(prob, st[1])
    polished = jax.vmap(lambda p, b, r0: _polish_one(p, cfg, b, r0))(
        prob, beta, best0)
    beta = jnp.where(active[..., None], polished, beta)
    out = _results_batched(prob, beta)
    if return_duals:
        info = AdmmSolveInfo(duals=AdmmDuals(nu=st[3], xi=st[4], zeta=st[5]),
                             iters=st[7])
        return out + (info,)
    return out


def _finalize_batched(prob, cfg, beta):
    """Project + polish, compacting to the polish-active instances (most
    fleets exit on the greedy-prefix bound and skip the scan entirely)."""
    beta, best0, active = _project_batched(prob, beta)
    act = np.flatnonzero(np.asarray(active))
    if act.size:
        pad, _ = pad_to_bucket(act)
        beta = _polish_apply(prob, cfg, beta, best0, jnp.asarray(pad))
    return _results_batched(prob, beta)


# --- host-driven compaction loop (bucketing: sched/compaction.py) ------------------

@jax.jit
def _compact(sub, st, idx, invalid):
    """Gather the still-active lanes of (problem, state) into a bucket in
    one compiled call (eager per-leaf gathers dispatch ~1 ms each on CPU);
    pad-duplicate lanes arrive pre-frozen via ``invalid``."""
    sub = _take(sub, idx)
    st = _take(st, idx)
    return sub, st[:6] + (st[6] | invalid,) + st[7:]


def admm_solve_batched(prob: BatchedProblem,
                       cfg: Optional[SchedConfig] = None,
                       duals: Optional[AdmmDuals] = None,
                       return_duals: bool = False,
                       warm_beta: Optional[jnp.ndarray] = None):
    """Solve B independent P2 instances in one device-resident pass.

    Returns (β (B, U), b_t (B,), R_t (B,)); with ``return_duals=True``
    also an ``AdmmSolveInfo`` whose exit multipliers warm-start the next
    nearby solve (the serve loop carries them tick to tick, DESIGN.md
    §15) and whose ``iters`` count each lane's outer iterations.
    ``warm_beta`` seeds the primal from a cached schedule, projected
    feasible (see ``_init_state``); it is measured-not-faster than
    dual-only warm starts and forfeits cold-start bitwise parity, so
    nothing in the repo passes it by default."""
    cfg = cfg or _DEFAULT
    B, U = prob.B, prob.U
    beta_out = np.zeros((B, U), np.float32)
    # exit-state collection: (nu, xi, zeta) at st[3:6], iterations at st[7]
    dual_out = [np.zeros((B, U), np.float32) for _ in range(3)]
    iters_out = np.zeros(B, np.int32)
    idx = np.arange(B)                       # original slot of each lane
    valid = np.ones(B, bool)                 # False for pad duplicates
    sub, st = prob, _init_batched(prob, cfg, duals, warm_beta)

    def retire(fin):
        slots = idx[fin]
        beta_out[slots] = np.asarray(st[1])[fin]
        for out, leaf in zip(dual_out, st[3:6]):
            out[slots] = np.asarray(leaf)[fin]
        iters_out[slots] = np.asarray(st[7])[fin]

    while True:
        st = _chunk_batched(sub, cfg, st)
        done = np.asarray(st[6]) | (np.asarray(st[7]) >= cfg.max_iters)
        active = ~done & valid
        if not active.any():
            retire(done & valid)
            break
        if _bucket(int(active.sum())) < idx.size:
            # compact: retire finished lanes, gather the rest into the
            # next pow2 bucket (pad duplicates arrive pre-frozen/invalid
            # — they never write results)
            retire(done & valid)
            pad, real = pad_to_bucket(np.flatnonzero(active))
            idx = idx[pad]
            valid = real
            sub, st = _compact(sub, st, jnp.asarray(pad),
                               jnp.asarray(~valid))
    beta = jnp.asarray(beta_out)
    out = _finalize_batched(prob, cfg, beta)
    if return_duals:
        info = AdmmSolveInfo(
            duals=AdmmDuals(*(jnp.asarray(d) for d in dual_out)),
            iters=jnp.asarray(iters_out))
        return out + (info,)
    return out
