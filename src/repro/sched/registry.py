"""Pluggable P2 scheduler registry — one entry point for §IV (Algorithms
1-2 and beyond), mirroring the ``repro.decode`` pattern (DESIGN.md §10).

``schedule(problem, method, cfg)`` dispatches on a registry name and on
the problem's batching:

- a NumPy reference ``Problem`` returns NumPy ``(β (U,), b_t, R_t)`` —
  drop-in for the FL server's per-round call;
- a ``BatchedProblem`` returns device arrays ``(β (B, U), b_t (B,),
  R_t (B,))`` — the fleet path, one call per round for all cells.

Built-ins:

  all              schedule everyone; b_t on the power boundary
  enum             Algorithm 1, exact O(2^U) (reference, small U)
  admm             Algorithm 2 + flip-polish (NumPy reference oracle)
  greedy           prefix search, loop form (reference oracle)
  admm_batched     Algorithm 2 vmapped, host-compacted between scan
                   chunks — the fleet path (repro.sched.admm)
  admm_batched_jit scan-safe Algorithm 2 (lax.while_loop, no host
                   compaction) — what the FL engine inlines (DESIGN §11)
  greedy_batched   vectorized/Pallas prefix sweep (repro.sched.greedy)

Single instances lift to B = 1 for the batched entries; batched problems
loop per instance through the reference entries (the parity/bench path).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np

from repro.sched import reference as ref
from repro.sched.admm import admm_solve_batched, admm_solve_batched_jit
from repro.sched.config import SchedConfig
from repro.sched.greedy import greedy_solve_batched
from repro.sched.problem import BatchedProblem
from repro.sched.reference import Problem


@dataclass(frozen=True)
class Scheduler:
    """Registry entry: solver fn + whether it consumes batched problems."""
    fn: Callable
    batched: bool = False


_REGISTRY: Dict[str, Scheduler] = {}


def register_scheduler(name: str, *, batched: bool = False):
    """Register ``fn(problem, cfg) -> (beta, b_t, r)`` under ``name``.
    ``batched=True`` entries take a ``BatchedProblem``; others take the
    NumPy reference ``Problem``."""
    def deco(fn):
        _REGISTRY[name] = Scheduler(fn=fn, batched=batched)
        return fn
    return deco


def get_scheduler(name: str) -> Scheduler:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scheduling method {name!r}; registered: "
                         f"{', '.join(list_schedulers())}") from None


def list_schedulers():
    return sorted(_REGISTRY)


def _unbatch(beta, b_t, r):
    return (np.asarray(beta[0], np.float64), float(b_t[0]), float(r[0]))


def schedule(problem: Union[Problem, BatchedProblem], method: str = "greedy",
             cfg: Optional[SchedConfig] = None
             ) -> Tuple[np.ndarray, float, float]:
    """Solve P2 with the scheduler registered under ``method``.

    Returns ``(β, b_t, R_t)`` — NumPy scalars/arrays for a single
    ``Problem``, device arrays for a ``BatchedProblem`` (see module
    docstring)."""
    sched = get_scheduler(method)
    single = isinstance(problem, Problem)
    if sched.batched:
        bp = BatchedProblem.single(problem) if single else problem
        out = sched.fn(bp, cfg)
        return _unbatch(*out) if single else out
    if single:
        return sched.fn(problem, cfg)
    # batched problem through a per-instance reference solver
    outs = [sched.fn(problem.instance(b), cfg) for b in range(problem.B)]
    return (np.stack([o[0] for o in outs]),
            np.asarray([o[1] for o in outs]),
            np.asarray([o[2] for o in outs]))


# --- built-ins --------------------------------------------------------------------

@register_scheduler("all")
def _all(prob: Problem, cfg):
    beta = np.ones(prob.U)
    b_t = ref.optimal_bt(prob, beta)
    return beta, b_t, ref._rt(prob, beta, b_t)


@register_scheduler("enum")
def _enum(prob: Problem, cfg):
    return ref.enumerate_solve(prob)


@register_scheduler("admm")
def _admm(prob: Problem, cfg):
    kw = {}
    if cfg is not None:
        kw = dict(c_step=cfg.c_step, max_iters=cfg.max_iters,
                  abs_tol=cfg.abs_tol, rel_tol=cfg.rel_tol)
    return ref.admm_solve(prob, **kw)


@register_scheduler("greedy")
def _greedy(prob: Problem, cfg):
    return ref.greedy_solve(prob)


@register_scheduler("admm_batched", batched=True)
def _admm_batched(prob: BatchedProblem, cfg):
    return admm_solve_batched(prob, cfg)


@register_scheduler("admm_batched_jit", batched=True)
def _admm_batched_jit(prob: BatchedProblem, cfg):
    # the scan-safe ADMM the FL engine inlines in its round body
    # (DESIGN.md §11); exposed here so host callers hit the same program
    return admm_solve_batched_jit(prob, cfg)


@register_scheduler("greedy_batched", batched=True)
def _greedy_batched(prob: BatchedProblem, cfg):
    return greedy_solve_batched(prob, cfg)
