"""Vectorized greedy prefix scheduler — sort + cumsum + argmin, no loop.

The reference ``greedy_solve`` walks the channel-cap order adding one
worker at a time and re-evaluating R_t — O(U) evaluations of an O(U)
objective. Because R_t depends on β only through the prefix length, the
prefix weight mass ΣK_i (a cumulative sum) and the prefix min-cap (the
last element under the descending sort), the whole sweep collapses to one
batched expression over the sorted arrays (DESIGN.md §10):

    s2 = cumsum(K_sorted);  R_j = R(s1 = j+1, s2_j, caps_sorted_j);  argmin

exact for equal K_i (the optimum is always a prefix of this ordering —
see the reference docstring), one device call for B instances, and the
selected β/b_t are bit-identical to the loop's: both pick elements of the
same sorted cap array.

At large U the (B, U) evaluation sweep routes through the Pallas kernel
(``kernels/prefix_eval.py``, ``SchedConfig.use_kernel``) — tiled,
sort-free, segmented; bit-for-bit with the jnp path in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.prefix_eval import N_COEF, prefix_eval
from repro.sched.config import SchedConfig
from repro.sched.problem import BatchedProblem, rt_from_stats

_DEFAULT = SchedConfig()


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pack_coefs(prob: BatchedProblem) -> jnp.ndarray:
    """(B, 8) f32 [Ktot, ρ1, A, E, N, 0, 0, 0] — the kernel's per-row
    scalar block; the jnp path slices the same array so both paths consume
    identical f32 coefficients."""
    ktot, rho1, A, E, N = prob.rt_coefs()
    B = ktot.shape[0]
    cols = [ktot, jnp.broadcast_to(jnp.float32(rho1), (B,)),
            jnp.broadcast_to(jnp.float32(A), (B,)),
            jnp.broadcast_to(jnp.float32(E), (B,)), N]
    coefs = jnp.stack([c.astype(jnp.float32) for c in cols], axis=-1)
    return jnp.pad(coefs, ((0, 0), (0, N_COEF - coefs.shape[-1])))


def prefix_sweep(caps_sorted: jnp.ndarray, k_sorted: jnp.ndarray,
                 coefs: jnp.ndarray) -> jnp.ndarray:
    """jnp reference for the prefix-R_t sweep — the kernel's parity oracle
    (same formula, same f32 coefficient array, full-row cumsum)."""
    s2 = jnp.cumsum(k_sorted, axis=-1)
    s1 = jax.lax.broadcasted_iota(jnp.float32, k_sorted.shape, 1) \
        + jnp.float32(1.0)
    return rt_from_stats(s1, s2, caps_sorted, ktot=coefs[:, 0:1],
                         rho1=coefs[:, 1:2], A=coefs[:, 2:3],
                         E=coefs[:, 3:4], N=coefs[:, 4:5])


@functools.partial(jax.jit, static_argnames="cfg")
def _greedy_batched(prob: BatchedProblem, cfg: SchedConfig):
    caps = prob.caps()                                   # (B, U)
    B, U = caps.shape
    order = jnp.argsort(-caps, axis=-1)
    caps_s = jnp.take_along_axis(caps, order, axis=-1)
    k_s = jnp.take_along_axis(prob.k_weights, order, axis=-1)
    coefs = pack_coefs(prob)
    if cfg.use_kernel:
        interpret = (cfg.interpret if cfg.interpret is not None
                     else _interpret_default())
        r = prefix_eval(caps_s, k_s, coefs, interpret=interpret,
                        tiles=cfg.kernel_tiles)
    else:
        r = prefix_sweep(caps_s, k_s, coefs)
    j = jnp.argmin(r, axis=-1)                           # (B,)
    b_t = jnp.take_along_axis(caps_s, j[:, None], axis=-1)[:, 0]
    r_best = jnp.take_along_axis(r, j[:, None], axis=-1)[:, 0]
    ranks = jax.lax.broadcasted_iota(jnp.int32, (B, U), 1)
    beta_sorted = (ranks <= j[:, None]).astype(caps.dtype)
    beta = jnp.zeros_like(caps).at[
        jnp.arange(B)[:, None], order].set(beta_sorted)
    return beta, b_t, r_best


def greedy_solve_batched(prob: BatchedProblem,
                         cfg: Optional[SchedConfig] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Schedule B instances with the prefix solver in one device call.

    Returns (β (B, U), b_t (B,), R_t (B,))."""
    return _greedy_batched(prob, cfg or _DEFAULT)
