"""Pow2 convergence-compaction bucketing (DESIGN.md §10/§15).

The fleet solvers never pay for a ragged active set: host-side callers
gather the lanes that still need device work (unconverged ADMM instances,
dirty serve-loop cells) into the next power-of-two bucket, padded by
repeating the first entry. The invariants every consumer relies on:

- **Bounded jit entries.** Bucket sizes are powers of two floored at
  ``MIN_BUCKET``, so a caller dispatching per-bucket jitted programs
  compiles at most log2(B) shapes, however the active-set size drifts.
- **Collision-safe scatters.** Pad lanes duplicate the first real index:
  a deterministic solver maps identical inputs to identical outputs, so
  scattering a bucket's results back with ``.at[pad].set`` writes the
  same value through every duplicate — no masking needed on the write
  path. The ``valid`` mask marks the real lanes for callers that do need
  to treat pads specially (e.g. the ADMM loop pre-freezes them).

Shared by ``sched/admm.py`` (convergence compaction between scan chunks,
flip-polish gather) and the continuous scheduling service
(``repro.serve``: dirty-cell batching) — extracted so both bucket
identically; the refactor is pinned bitwise by tests/test_serve.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

MIN_BUCKET = 8     # smallest compaction bucket


def bucket(n: int, min_bucket: int = MIN_BUCKET) -> int:
    """Smallest power of two ≥ ``n``, floored at ``min_bucket``."""
    if n <= 0:
        raise ValueError(f"bucket needs n >= 1, got {n}")
    return max(min_bucket, 1 << (n - 1).bit_length())


def take(tree, idx):
    """Gather every leaf of a pytree at ``idx`` (lane gather)."""
    return jax.tree_util.tree_map(lambda l: l[idx], tree)


def pad_to_bucket(idx: np.ndarray, min_bucket: int = MIN_BUCKET
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad an active-lane index set to its pow2 bucket.

    Returns ``(pad, valid)``: ``pad`` is ``idx`` followed by repeats of
    ``idx[0]`` up to ``bucket(len(idx))`` entries, ``valid`` marks the
    real (non-duplicate) lanes. See the module docstring for why the
    duplicate-pad convention makes result scatters collision-safe."""
    idx = np.asarray(idx)
    if idx.size == 0:
        raise ValueError("pad_to_bucket needs at least one active lane")
    size = bucket(int(idx.size), min_bucket)
    pad = np.concatenate([idx, np.repeat(idx[:1], size - idx.size)])
    valid = np.zeros(size, bool)
    valid[:idx.size] = True
    return pad, valid
