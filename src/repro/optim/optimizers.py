"""Optimizers from scratch (no optax in this container).

The paper uses plain GD (``sgd``); momentum/adam are substrate options.
Every optimizer works on ANY pytree of arrays — including a single
chunked ``(n_chunks, D_c)`` master array, which is how the zoo-scale
round (engine/zoo_train.py, DESIGN.md §17) carries its moments: the
``update`` math is elementwise, so the same ``Optimizer`` that steps a
params pytree steps a shard-local master block inside ``shard_map``.

``ef_step`` is THE error-feedback correction (Stich et al., paper's
ref. [37]) — the single implementation behind ``with_error_feedback``,
the §11 engine's fused EF split, and the zoo round's sharded residual
carry (one algorithm, one code path, DESIGN.md §17).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable        # params -> state
    update: Callable      # (grads, state, params, lr) -> (new_params, state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g.astype(p.dtype),
                                     params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        new_m = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            step = jax.tree_util.tree_map(
                lambda m, g: beta * m + g.astype(jnp.float32), new_m, grads)
        else:
            step = new_m
        new = jax.tree_util.tree_map(
            lambda p, s: p - lr * s.astype(p.dtype), params, step)
        return new, new_m

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.copy, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m_, v_: p - (lr * (m_ / bc1)
                                   / (jnp.sqrt(v_ / bc2) + eps)).astype(
                                       p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adam": adam}


def make(name: str, **kw) -> Optimizer:
    """Build a registered optimizer by name; the single registry behind
    the train CLI, the §11 engine, and the zoo-scale round carries
    (DESIGN.md §17)."""
    if name not in OPTIMIZERS:
        raise ValueError(
            f"optimizer {name!r} is not registered; choose one of "
            f"{' | '.join(sorted(OPTIMIZERS))}")
    return OPTIMIZERS[name](**kw)


def ef_step(grads, residual, approx_fn: Callable) -> Tuple:
    """One error-feedback step (Stich et al., paper's ref. [37]):
    corrected = g + e; (out, approx) = approx_fn(corrected);
    e' = corrected − approx.

    ``approx_fn`` maps the corrected gradient to ``(out, approx)`` where
    ``out`` is whatever the caller transmits (a wire representation, the
    sparse vector itself, ...) and ``approx`` is the lossy approximation
    ACTUALLY applied, in the corrected gradient's own space — the residual
    accumulates exactly what the uplink dropped. Returns
    ``(out, new_residual, corrected)``. This is the one shared EF
    implementation (engine/core.py's fused split, the zoo round's sharded
    carry, and ``with_error_feedback`` all call it; DESIGN.md §17)."""
    corrected = grads + residual
    out, approx = approx_fn(corrected)
    return out, corrected - approx, corrected


def with_error_feedback(compress_fn: Callable) -> Callable:
    """EF wrapper for the FL aggregation path: maintains a per-worker
    residual e; transmits compress(g + e); e' = (g + e) − decompressed.

    compress_fn: flat -> (wire_repr, decompressed_flat). Returns a function
    (flat_grad, residual) -> (wire_repr, new_residual)."""
    def apply(flat_grad, residual):
        wire, new_residual, _ = ef_step(flat_grad, residual, compress_fn)
        return wire, new_residual

    return apply
