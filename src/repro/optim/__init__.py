from repro.optim.optimizers import (Optimizer, adam, momentum, sgd,
                                    with_error_feedback)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = ["Optimizer", "adam", "momentum", "sgd", "with_error_feedback",
           "constant", "cosine_decay", "warmup_cosine"]
