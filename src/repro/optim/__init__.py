from repro.optim.optimizers import (OPTIMIZERS, Optimizer, adam, ef_step,
                                    make, momentum, sgd, with_error_feedback)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = ["OPTIMIZERS", "Optimizer", "adam", "ef_step", "make", "momentum",
           "sgd", "with_error_feedback", "constant", "cosine_decay",
           "warmup_cosine"]
