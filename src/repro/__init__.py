"""repro: 1-bit CS federated learning over the air — production JAX framework."""

__version__ = "1.0.0"
