"""gemma2-2b [dense] — local+global alternating, logit softcap [arXiv:2408.00118]."""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab_size=256000,
    attention=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=256,
                              rope_theta=10_000.0, window=4096,
                              logit_softcap=50.0),
    local_global_period=2,          # alternate local, global (period 2)
    final_logit_softcap=30.0,
    tie_embeddings=True,
    source="[arXiv:2408.00118] Gemma 2",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma2-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=64,
                                  rope_theta=10_000.0, window=64,
                                  logit_softcap=50.0))
