"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 mamba2 layers; a single weight-tied (shared) attention+MLP block is applied
every 6 mamba layers (13 applications).
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,                     # shared block MLP
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=2,
                  chunk_size=256, conv_width=4),
    attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=112,
                              rope_theta=10_000.0),
    hybrid_attn_every=6,
    tie_embeddings=True,
    source="[arXiv:2411.15242] Zamba2",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512, hybrid_attn_every=2,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, n_groups=2,
                      chunk_size=32, conv_width=4),
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=64,
                                  rope_theta=10_000.0))
