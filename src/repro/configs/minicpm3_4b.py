"""minicpm3-4b [dense] — MLA [hf:openbmb/MiniCPM3-4B]."""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    attention=AttentionConfig(num_heads=40, num_kv_heads=40,
                              rope_theta=10_000.0,
                              use_mla=True, kv_lora_rank=256, q_lora_rank=768,
                              qk_nope_dim=64, qk_rope_dim=32,
                              v_head_dim=64, head_dim=96),
    tie_embeddings=True,
    source="[hf:openbmb/MiniCPM3-4B]",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4,
                                  rope_theta=10_000.0,
                                  use_mla=True, kv_lora_rank=64, q_lora_rank=128,
                                  qk_nope_dim=32, qk_rope_dim=16,
                                  v_head_dim=32, head_dim=48))
