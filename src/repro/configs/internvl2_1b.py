"""internvl2-1b [vlm] — InternViT + InternLM2/Qwen2 backbone [arXiv:2404.16821].

The vision frontend (InternViT + MLP projector) is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, num_image_tokens, d_model); we implement the language decoder that
consumes them interleaved with text tokens.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151655,
    attention=AttentionConfig(num_heads=14, num_kv_heads=2, head_dim=64,
                              rope_theta=1_000_000.0),
    num_image_tokens=256,
    tie_embeddings=True,
    source="[arXiv:2404.16821] InternVL2 (Qwen2-0.5B LM backbone)",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512, num_image_tokens=16,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=64,
                                  rope_theta=1_000_000.0))
