"""gemma3-27b [dense] — 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt]."""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab_size=262144,
    attention=AttentionConfig(num_heads=32, num_kv_heads=16, head_dim=128,
                              rope_theta=1_000_000.0, window=1024),
    local_global_period=6,          # 5 local : 1 global
    tie_embeddings=True,
    source="[hf:google/gemma-3-1b-pt] Gemma 3 family",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512, local_global_period=2,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=64,
                                  rope_theta=1_000_000.0, window=64))
