"""Config system: one dataclass family covering all assigned architectures.

Every architecture file in this package exports ``CONFIG: ModelConfig`` with
the exact assigned dimensions, plus ``smoke_config()`` returning a reduced
variant (<=2 layers, d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8            # routed experts
    num_shared_experts: int = 0     # always-on experts (DeepSeek style)
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01   # load-balance loss coefficient


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block hyperparameters."""
    d_state: int = 128
    head_dim: int = 64              # SSD head dim (P)
    expand: int = 2                 # d_inner = expand * d_model
    n_groups: int = 8               # B/C groups (shardable)
    chunk_size: int = 256           # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0               # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    # Sliding window: 0 = full attention. For alternating patterns,
    # layer_pattern controls which layers are local.
    window: int = 0
    logit_softcap: float = 0.0      # gemma2-style attention softcap (0 = off)
    # MLA (DeepSeek / MiniCPM3 latent attention)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0            # 0 -> no q compression
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio | mlp
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Alternating local/global attention: period p with `global_every`
    # meaning layer i is GLOBAL iff (i % p) == p-1. "" = uniform.
    layer_pattern: str = ""         # e.g. "local:global" period via fields below
    local_global_period: int = 0    # 0 = all layers per attention.window
    # hybrid (zamba2): shared attention block applied every N mamba layers
    hybrid_attn_every: int = 0
    # enc-dec (whisper)
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0        # stub frontend frames (whisper: 1500)
    # vlm: number of stub image-patch embeddings prepended
    num_image_tokens: int = 0
    final_logit_softcap: float = 0.0
    gated_mlp: bool = True          # SwiGLU (3 mats) vs GELU MLP (2 mats)
    # §Perf: flash-decoding partial-softmax over a length-sharded KV cache
    # (0 = off -> all-gather decode attention). See attention.py.
    decode_sharded_chunks: int = 0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    source: str = ""                # citation

    @property
    def head_dim(self) -> int:
        a = self.attention
        if a is None:
            return 0
        return a.head_dim if a.head_dim else self.d_model // a.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic mechanism available (SSM, hybrid, or sliding window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        a = self.attention
        if a is None:
            return False
        return a.window > 0 or self.local_global_period > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for rooflines)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.family == "ssm" or self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            per_layer += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
            per_layer += s.conv_width * conv_dim       # conv1d
            per_layer += nheads * 2                    # A_log, D
            per_layer += nheads                        # dt_bias
            per_layer += d_in * d                      # out_proj
            per_layer += d                             # norm
            per_layer += d_in                          # gated rmsnorm
        if self.attention is not None and self.family != "ssm":
            a = self.attention
            hd = self.head_dim
            if a.use_mla:
                qd = a.qk_nope_dim + a.qk_rope_dim
                if a.q_lora_rank:
                    per_layer += d * a.q_lora_rank + a.q_lora_rank * a.num_heads * qd
                    per_layer += a.q_lora_rank         # q norm
                else:
                    per_layer += d * a.num_heads * qd
                per_layer += d * (a.kv_lora_rank + a.qk_rope_dim)
                per_layer += a.kv_lora_rank            # kv norm
                per_layer += a.kv_lora_rank * a.num_heads * (a.qk_nope_dim + a.v_head_dim)
                per_layer += a.num_heads * a.v_head_dim * d
            else:
                per_layer += d * a.num_heads * hd      # q
                per_layer += 2 * d * a.num_kv_heads * hd  # k,v
                per_layer += a.num_heads * hd * d      # o
            per_layer += d                             # attn norm
        n_mats = 3 if self.gated_mlp else 2
        if self.family == "moe":
            m = self.moe
            per_layer += d * m.num_experts             # router
            per_layer += m.num_experts * n_mats * d * self.d_ff
            per_layer += m.num_shared_experts * n_mats * d * self.d_ff
            per_layer += d                             # ffn norm
        elif self.d_ff > 0 and self.family != "hybrid":
            per_layer += n_mats * d * self.d_ff        # mlp
            per_layer += d                             # ffn norm
        if self.family == "hybrid":
            n += self.num_layers * per_layer
            # one shared attention + mlp block
            a = self.attention
            hd = self.head_dim
            shared = d * a.num_heads * hd + 2 * d * a.num_kv_heads * hd + a.num_heads * hd * d
            shared += (3 if self.gated_mlp else 2) * d * self.d_ff + 2 * d
            n += shared
        else:
            n += self.num_layers * per_layer
        if self.num_encoder_layers:
            a = self.attention
            hd = self.head_dim
            enc_layer = d * a.num_heads * hd * 2 + 2 * d * a.num_kv_heads * hd * 2  # self+cross? enc has self only
            enc_layer = (d * a.num_heads * hd + 2 * d * a.num_kv_heads * hd
                         + a.num_heads * hd * d
                         + (3 if self.gated_mlp else 2) * d * self.d_ff + 2 * d)
            n += self.num_encoder_layers * enc_layer
            # decoder cross-attention (added on top of self-attn counted above)
            cross = (d * a.num_heads * hd + 2 * d * a.num_kv_heads * hd
                     + a.num_heads * hd * d + d)
            n += self.num_layers * cross
        n += d                                         # final norm
        return n


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """Trainer / FL-aggregation knobs (the paper's technique lives here)."""
    aggregation: str = "mean"       # mean | obcsaa | topk_aa
    optimizer: str = "sgd"          # sgd | momentum | adam  (paper: plain GD)
    learning_rate: float = 0.1
    # Per-worker error-feedback residual (Stich et al., §11/§17): the
    # residual accumulates what the 1-bit uplink dropped, so it only
    # means anything under the compressing aggregator.
    error_feedback: bool = False
    # OBCSAA knobs (paper notation)
    cs_chunk: int = 4096            # D_c  (chunked measurement, DESIGN.md §4)
    cs_measure: int = 1024          # S_c  (compressed rows per chunk)
    cs_topk: int = 409              # kappa_c per chunk (~10%)
    biht_iters: int = 5
    # 1-bit CS decoder (repro.decode registry, DESIGN.md §9):
    # iht | niht | biht | iht_warm | iht_fused
    cs_decoder: str = "biht"
    # Decoder step size. biht uses tau/S (paper §V; 1.0 is the paper
    # setting). The fixed-step iht family needs tau below the restricted
    # operator norm — ~0.25 at the default decode budget kappa_bar = S_c/2
    # (see benchmarks/decoders_bench.py); niht adapts and ignores this.
    cs_tau: float = 1.0
    noise_var: float = 1e-4         # sigma^2 (mW)
    p_max: float = 10.0             # P^Max (mW)
    # §Perf knobs (beyond-paper; False/f32 = paper-faithful baseline)
    cs_shard_aligned: bool = False  # chunk along the model-sharded dim
    cs_packed: bool = False         # 32-signs-per-uint32 wire format (§13)
    wire_dtype: str = "float32"     # MAC symbol dtype (bf16 halves psum B/W)
    remat: bool = True
    # Remat granularity for the scanned layer stack (DESIGN.md §16):
    # None -> derive from the bool `remat` ("full" / "off"); otherwise one
    # of "off" | "full" | "dots" | "dots_no_batch".
    remat_policy: Optional[str] = None
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        # Eager geometry validation: a packed uplink rides 32 signs per
        # uint32 word, so S_c must pack evenly. Failing here (at config
        # construction) names the field; failing later surfaces as an
        # opaque reshape error deep in the Pallas kernels.
        if self.cs_packed and self.cs_measure % 32 != 0:
            raise ValueError(
                f"TrainConfig.cs_measure={self.cs_measure} does not satisfy "
                f"the packed-wire geometry: cs_packed=True needs "
                f"cs_measure % 32 == 0 (32 signs per uint32 word, "
                f"DESIGN.md §13). Pick a multiple of 32 or set "
                f"cs_packed=False.")
        valid_remat = (None, "off", "full", "dots", "dots_no_batch")
        if self.remat_policy not in valid_remat:
            raise ValueError(
                f"TrainConfig.remat_policy={self.remat_policy!r} not in "
                f"{valid_remat}")
        valid_opt = ("sgd", "momentum", "adam")
        if self.optimizer not in valid_opt:
            raise ValueError(
                f"TrainConfig.optimizer={self.optimizer!r} is not a "
                f"registered optimizer; choose one of "
                f"{' | '.join(valid_opt)} (repro.optim.OPTIMIZERS)")
        if self.error_feedback and self.aggregation != "obcsaa":
            raise ValueError(
                f"TrainConfig.error_feedback=True needs "
                f"aggregation='obcsaa': the EF residual accumulates what "
                f"the 1-bit compressed uplink dropped (DESIGN.md §11/§17) "
                f"— under aggregation={self.aggregation!r} nothing is "
                f"dropped and the residual geometry is undefined. Set "
                f"aggregation='obcsaa' or error_feedback=False.")

    @property
    def remat_mode(self):
        """Effective remat knob for ``models.transformer.remat_wrap``."""
        if self.remat_policy is not None:
            return self.remat_policy
        return "full" if self.remat else "off"


def scaled(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
