"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173]."""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    d_ff=24576,
    vocab_size=49152,
    attention=AttentionConfig(num_heads=48, num_kv_heads=4, head_dim=128,
                              rope_theta=100_000.0),
    gated_mlp=False,
    tie_embeddings=False,
    source="[arXiv:2402.19173] StarCoder2",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=64,
                                  rope_theta=100_000.0))
