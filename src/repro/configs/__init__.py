"""Architecture config registry.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` resolve the assigned
architecture ids (``--arch`` flags use these exact strings).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (AttentionConfig, INPUT_SHAPES, InputShape,
                                ModelConfig, MoEConfig, SSMConfig,
                                TrainConfig, dtype_of, scaled)

ARCH_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "starcoder2-15b": "starcoder2_15b",
    "internvl2-1b": "internvl2_1b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-base": "whisper_base",
    "gemma2-2b": "gemma2_2b",
    "minicpm3-4b": "minicpm3_4b",
    "zamba2-7b": "zamba2_7b",
    "gemma3-27b": "gemma3_27b",
    "mnist-mlp": "mnist_mlp",
}

ASSIGNED_ARCHS = [a for a in ARCH_MODULES if a != "mnist-mlp"]


def _module(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


__all__ = [
    "ARCH_MODULES", "ASSIGNED_ARCHS", "AttentionConfig", "INPUT_SHAPES",
    "InputShape", "ModelConfig", "MoEConfig", "SSMConfig", "TrainConfig",
    "dtype_of", "get_config", "get_smoke_config", "scaled",
]
