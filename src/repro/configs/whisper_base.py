"""whisper-base [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a stub: ``input_specs()``
provides (batch, 1500, d_model) precomputed frame embeddings. We implement
the encoder transformer + decoder transformer with cross-attention.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                   # decoder layers
    num_encoder_layers=6,
    encoder_seq_len=1500,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=64,
                              rope_theta=10_000.0),
    gated_mlp=False,
    tie_embeddings=True,
    source="[arXiv:2212.04356] Whisper",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, num_encoder_layers=2,
        encoder_seq_len=64, d_model=256, d_ff=512, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=64,
                                  rope_theta=10_000.0))
