"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    d_ff=0,                         # attention-free, no separate MLP
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=8,
                  chunk_size=256, conv_width=4),
    attention=None,
    tie_embeddings=True,
    source="[arXiv:2405.21060] Mamba-2 / SSD",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", num_layers=2, d_model=256, vocab_size=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, n_groups=2,
                      chunk_size=32, conv_width=4))
