"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].

Deviation (DESIGN.md §8): all 27 layers are MoE (real model's layer 0 is
dense); assignment specifies the uniform "MoE 64e top-6" stack.
"""
import dataclasses

from repro.configs.base import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    d_ff=1408,                      # per-expert intermediate
    vocab_size=102400,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                              rope_theta=10_000.0,
                              use_mla=True, kv_lora_rank=512, q_lora_rank=0,
                              qk_nope_dim=128, qk_rope_dim=64,
                              v_head_dim=128, head_dim=192),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  capacity_factor=1.25),
    tie_embeddings=False,
    source="[arXiv:2405.04434] DeepSeek-V2 (Lite)",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-lite-smoke", num_layers=2, d_model=256,
        d_ff=128, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4,
                                  rope_theta=10_000.0,
                                  use_mla=True, kv_lora_rank=64, q_lora_rank=0,
                                  qk_nope_dim=32, qk_rope_dim=16,
                                  v_head_dim=32, head_dim=48),
        moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                      capacity_factor=1.25))
