"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""
import dataclasses

from repro.configs.base import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    attention=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0, window=4096),
    moe=MoEConfig(num_experts=8, num_shared_experts=0, top_k=2,
                  capacity_factor=1.25),
    tie_embeddings=False,
    source="[arXiv:2401.04088] Mixtral of Experts",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", num_layers=2, d_model=256, d_ff=512,
        vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=64,
                                  rope_theta=1_000_000.0, window=64),
        moe=MoEConfig(num_experts=4, num_shared_experts=0, top_k=2,
                      capacity_factor=1.25))
