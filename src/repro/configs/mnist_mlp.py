"""The paper's own model: MLP 784-64-10, D = 50,890 parameters (Section V)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mnist-mlp",
    family="mlp",
    num_layers=1,                   # one hidden layer
    d_model=64,                     # hidden width
    d_ff=784,                       # input dim (re-used field)
    vocab_size=10,                  # classes
    tie_embeddings=False,
    source="paper §V: MLP 784-64-10, D=50890",
)


def smoke_config() -> ModelConfig:
    return CONFIG  # already tiny
