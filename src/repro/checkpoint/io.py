"""msgpack-based pytree checkpointing (no orbax in this container).

Layout: <dir>/step_<N>/{tree.msgpack, arrays.npz}. Arrays are stored in an
npz (zero-copy reload); the msgpack holds the treedef + leaf metadata.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
        # npz cannot roundtrip ml_dtypes (bfloat16 etc.); store as f32,
        # the leaf dtype is recorded in meta and restored on load
        arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
    return arr


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [(jax.tree_util.keystr(path), _to_numpy(leaf))
              for path, leaf in flat[0]]
    return leaves, flat[1]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
    meta = {"keys": [k for k, _ in leaves],
            "dtypes": [str(a.dtype) for _, a in leaves],
            "step": step}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "tree.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    return path


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "tree.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    arrays = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, template has "
                         f"{len(flat)}")
    restored = [jax.numpy.asarray(a).astype(l.dtype).reshape(l.shape)
                for a, l in zip(arrays, flat)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None
