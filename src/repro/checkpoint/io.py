"""msgpack-based pytree checkpointing (no orbax in this container).

Layout: <dir>/step_<N>/{tree.msgpack, arrays.npz}. Arrays are stored in an
npz (zero-copy reload); the msgpack holds the treedef + leaf metadata.

Three properties the engine checkpoint wiring (DESIGN.md §14) leans on:

- **Atomic step dirs** — ``save`` writes into ``step_<N>.tmp`` and renames
  at the end, so a crash mid-write never leaves a half-written directory
  that ``latest_step`` would pick up (the tmp suffix fails its regex).
- **Template-strict restore** — ``restore`` validates leaf count, per-leaf
  shape and dtype against the ``like`` template and raises a ``ValueError``
  naming the offending leaf path; corrupted/truncated files surface as
  ``ValueError("corrupt or truncated checkpoint ...")`` instead of a raw
  zipfile/msgpack traceback.
- **Sharding-aware load** — pass ``shardings`` (a pytree of
  ``jax.sharding.Sharding`` matching ``like``) and every restored leaf is
  ``device_put`` onto its target sharding, so a checkpoint written on one
  mesh restores onto a differently-sized mesh; the bytes are mesh-layout
  independent (leaves are saved as full host arrays).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
        # npz cannot roundtrip ml_dtypes (bfloat16 etc.); store as f32,
        # the leaf dtype is recorded in meta and restored on load
        arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
    return arr


def _storage_dtype(dtype) -> str:
    """The on-disk dtype a template leaf is stored as: ml_dtypes leaves
    (bfloat16, ...) round-trip through float32 (see ``_to_numpy``),
    everything else is stored as-is."""
    d = np.dtype(dtype)
    if d.kind == "V" or "bfloat16" in str(d):
        return "float32"
    return str(d)


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [(jax.tree_util.keystr(path), _to_numpy(leaf))
              for path, leaf in flat[0]]
    return leaves, flat[1]


def step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write one checkpoint step atomically; returns the step directory."""
    path = step_dir(ckpt_dir, step)
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
    meta = {"keys": [k for k, _ in leaves],
            "dtypes": [str(a.dtype) for _, a in leaves],
            "shapes": [list(a.shape) for _, a in leaves],
            "step": step}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "tree.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.isdir(path):        # overwrite an existing step in place
        import shutil
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def _load_step(path: str):
    """(meta, arrays) of one step dir, or ValueError with a message that
    says WHICH file is corrupt/truncated and how to recover."""
    meta_p = os.path.join(path, "tree.msgpack")
    npz_p = os.path.join(path, "arrays.npz")
    try:
        with open(meta_p, "rb") as f:
            meta = msgpack.unpackb(f.read())
        if not isinstance(meta, dict) or "keys" not in meta:
            raise ValueError("meta is not a checkpoint dict")
    except Exception as e:
        raise ValueError(
            f"corrupt or truncated checkpoint meta {meta_p!r}: "
            f"{type(e).__name__}: {e}. Delete this step directory and "
            f"resume from an earlier step.") from e
    try:
        data = np.load(npz_p)
        arrays = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    except Exception as e:
        raise ValueError(
            f"corrupt or truncated checkpoint arrays {npz_p!r}: "
            f"{type(e).__name__}: {e}. Delete this step directory and "
            f"resume from an earlier step.") from e
    return meta, arrays


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template).

    ``like`` may hold real arrays or ``ShapeDtypeStruct`` leaves (e.g.
    from ``jax.eval_shape``). ``shardings``: optional pytree of
    ``jax.sharding.Sharding`` with the same structure — each restored
    leaf is ``device_put`` onto it (mesh-elastic restore, DESIGN.md §14).
    """
    path = step_dir(ckpt_dir, step)
    if not os.path.isdir(path):
        have = _steps(ckpt_dir)
        raise FileNotFoundError(
            f"no checkpoint step {step} under {ckpt_dir!r} "
            f"(available steps: {have or 'none'})")
    meta, arrays = _load_step(path)
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != len(arrays):
        raise ValueError(
            f"checkpoint {path!r} has {len(arrays)} leaves, template has "
            f"{len(flat)}; saved paths: {meta['keys'][:8]}... — was it "
            f"written by a differently-configured run?")
    restored = []
    for key, arr, l in zip(meta["keys"], arrays, flat):
        if tuple(arr.shape) != tuple(l.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)}, "
                f"template expects {tuple(l.shape)} — the run geometry "
                f"(D, U, arms, chunking) must match the saved sweep")
        want = _storage_dtype(l.dtype)
        if str(arr.dtype) != want:
            raise ValueError(
                f"checkpoint leaf {key!r} has dtype {arr.dtype}, template "
                f"expects {np.dtype(l.dtype)} (stored as {want}) — "
                f"optimizer moments and round carries restore "
                f"dtype-strict; a silent cast would break bitwise resume "
                f"(DESIGN.md §17). Re-save the checkpoint with the "
                f"template's dtypes or fix the restore template.")
        restored.append(jax.numpy.asarray(arr).astype(l.dtype))
    if shardings is not None:
        shard_flat = jax.tree_util.tree_leaves(shardings)
        if len(shard_flat) == len(restored):
            restored = [jax.device_put(a, s)
                        for a, s in zip(restored, shard_flat)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def _steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                  if (m := re.match(r"step_(\d+)$", d)))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _steps(ckpt_dir)
    return steps[-1] if steps else None
