from repro.checkpoint.io import latest_step, restore, save, step_dir

__all__ = ["latest_step", "restore", "save", "step_dir"]
