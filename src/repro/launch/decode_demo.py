"""Runnable batched decode demo: prefill + autoregressive decode.

  PYTHONPATH=src python -m repro.launch.decode_demo --arch gemma2-2b \
      --smoke --batch 4 --prompt-len 32 --gen 16

(Formerly ``repro.launch.serve`` — renamed because it demos model
decoding, not a serving system; the scheduling service lives in
``repro.serve``, DESIGN.md §15. ``repro.launch.serve`` remains as a
deprecation shim.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    model = build_model(cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)),
                              jnp.int32)
        decode = jax.jit(steps_lib.make_decode_step(model),
                         donate_argnums=(1,))
        # prefill by stepping the decode cache over the prompt (cheap at
        # smoke scale; production uses model.prefill + cache seeding)
        cache = model.init_cache(B, total)
        tok = prompts[:, :1]
        out_tokens = [tok]
        t0 = time.time()
        for pos in range(total - 1):
            if pos + 1 < P:
                nxt = prompts[:, pos + 1:pos + 2]
            else:
                logits, cache = decode(params, cache, tok, jnp.int32(pos))
                if args.temperature > 0:
                    key = jax.random.PRNGKey(pos)
                    nxt = jax.random.categorical(
                        key, logits[:, -1] / args.temperature)[:, None]
                else:
                    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                nxt = nxt.astype(jnp.int32)
                out_tokens.append(nxt)
            if pos + 1 < P:
                # still need to ingest the prompt token into the cache
                _, cache = decode(params, cache, tok, jnp.int32(pos))
            tok = nxt
        dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"generated {G} tokens x batch {B} in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s)")
    print("sample token ids:", np.asarray(gen[0])[:24].tolist())


if __name__ == "__main__":
    main()
