"""Production mesh definitions (MULTI-POD DRY-RUN spec).

Functions, not module-level constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_zoo_mesh(n_workers: int = 0, model_parallel: int = 0):
    """Mesh for sharded model-zoo rounds (engine/zoo.py, DESIGN.md §14):
    ``(n_workers, model_parallel)`` over ``("data", "model")`` on the
    local devices. Zeros pick defaults — every device used, model
    parallelism 2 when the device count allows it (the ≥1B CPU bench
    geometry: 4 FL workers × 2 model shards on an 8-device host mesh)."""
    n = len(jax.devices())
    if not model_parallel:
        model_parallel = 2 if n % 2 == 0 and n > 1 else 1
    if not n_workers:
        n_workers = n // model_parallel
    if n_workers * model_parallel != n:
        raise ValueError(
            f"make_zoo_mesh: {n_workers} workers x {model_parallel} model "
            f"shards != {n} local devices")
    return jax.make_mesh((n_workers, model_parallel), ("data", "model"))


def worker_axes(mesh) -> tuple:
    """Mesh axes that enumerate FL workers (DESIGN.md §3)."""
    return tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names)


def num_workers(mesh) -> int:
    n = 1
    for ax in worker_axes(mesh):
        n *= mesh.shape[ax]
    return n
