import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) combination:
  lower the step with ShapeDtypeStruct stand-ins, compile, and record
  memory_analysis / cost_analysis / per-collective byte counts parsed from
  the post-SPMD HLO. Results are cached as JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--agg obcsaa]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, TrainConfig, get_config
from repro.dist.sharding import best_spec
from repro.launch.mesh import make_production_mesh, num_workers
from repro.launch import steps as steps_lib
from repro.models.registry import build_model

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> .+ \{")
_WHILE_RE = re.compile(r"while\(.*?\)?, condition=%?([\w.\-]+), "
                       r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_computations(hlo_text: str):
    """name -> list of body lines (top-level computations in HLO text)."""
    comps = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps, entry


def _computation_multipliers(comps, entry):
    """Execution count of each computation: while bodies run trip_count
    times per parent invocation (nested whiles multiply)."""
    mult = {name: 0 for name in comps}
    if entry is not None:
        mult[entry] = 1
    # edges: parent -> (child, n) for body/condition of each while op
    edges = []
    for parent, lines in comps.items():
        for ls in lines:
            w = _WHILE_RE.search(ls)
            if not w:
                continue
            t = _TRIP_RE.search(ls)
            n = int(t.group(1)) if t else 1
            cond, body = w.group(1), w.group(2)
            edges.append((parent, body, n))
            edges.append((parent, cond, n + 1))
    for _ in range(len(comps)):   # fixpoint over nesting depth
        changed = False
        for parent, child, n in edges:
            v = mult.get(parent, 0) * n
            if child in mult and v > mult[child]:
                mult[child] = v
                changed = True
        if not changed:
            break
    return mult


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte accounting from post-SPMD HLO, scaled by while-
    loop trip counts (XLA's aggregate cost_analysis counts loop bodies once;
    scanned layer stacks would otherwise be undercounted ~num_layers x).

    Bytes per op: operand bytes when printed, else result bytes.
    ``wire_bytes`` approximates bytes crossing ICI per device: 2x for
    all-reduce (reduce+broadcast ring), 1x for the others."""
    comps, entry = _split_computations(hlo_text)
    mult = _computation_multipliers(comps, entry)
    out = {c: {"count": 0, "bytes": 0, "wire_bytes": 0} for c in _COLLECTIVES}
    for comp_name, lines in comps.items():
        k = mult.get(comp_name, 1) or 1
        for ls in lines:
            m = re.match(r"(?:ROOT )?%?[\w.\-]+ = .*?\b(all-gather|"
                         r"all-reduce|reduce-scatter|all-to-all|"
                         r"collective-permute)(?:-start|-done)?\(", ls)
            if not m:
                continue
            op = m.group(1)
            if "-done(" in ls:      # avoid double counting start/done pairs
                continue
            eq = ls.index(" = ")
            result_shapes = _SHAPE_RE.findall(ls[eq + 3:ls.index("(", eq)])
            operand_shapes = _SHAPE_RE.findall(ls[ls.index("(", eq):])
            rb = sum(_type_bytes(dt, dims) for dt, dims in result_shapes)
            ob = sum(_type_bytes(dt, dims) for dt, dims in operand_shapes)
            out[op]["count"] += k
            out[op]["bytes"] += k * (ob or rb)
            out[op]["wire_bytes"] += k * (2 * rb if op == "all-reduce"
                                          else max(rb, ob))
    out["total_bytes"] = sum(v["bytes"] for k_, v in out.items()
                             if isinstance(v, dict))
    out["total_wire_bytes"] = sum(v["wire_bytes"] for k_, v in out.items()
                                  if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k_, v in out.items()
                             if isinstance(v, dict))
    return out


def input_shardings(specs_tree, mesh):
    def visit(v):
        hints = ["data"] + [None] * (len(v.shape) - 1)
        return NamedSharding(mesh, best_spec(v.shape, hints, mesh))

    return jax.tree_util.tree_map(visit, specs_tree)


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                agg: str = "obcsaa", tcfg: TrainConfig = None,
                variant: str = "baseline"):
    """Build + lower + compile one combination. Returns result dict.

    variant="opt" enables the §Perf beyond-paper changes: shard-aligned
    chunking + bf16 MAC symbols (train), flash-decoding sharded-cache
    attention (decode)."""
    import dataclasses
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if variant == "opt":
        if shape_name in ("decode_32k", "long_500k"):
            cfg = dataclasses.replace(cfg, decode_sharded_chunks=16)
        # NOTE: wire_dtype="bfloat16" is the TPU deployment choice, but the
        # XLA *CPU* AllReducePromotion pass crashes on bf16 all-reduce
        # ("Invalid binary instruction opcode copy") — keep f32 on the CPU
        # stand-in and record bf16's 2x saving analytically (EXPERIMENTS §Perf).
        tcfg = tcfg or TrainConfig(aggregation=agg, cs_shard_aligned=True)
    if shape.kind == "decode" and shape_name == "long_500k" \
            and not cfg.supports_long_context:
        return {"status": "skipped",
                "reason": "full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    tcfg = tcfg or TrainConfig(aggregation=agg)
    t0 = time.time()
    with jax.set_mesh(mesh):
        pshard, pshapes = steps_lib.param_shardings(model, mesh)
        specs = model.input_specs(shape)
        in_shard = input_shardings(specs, mesh)
        if shape.kind == "train":
            step = steps_lib.make_train_step(model, tcfg, mesh)
            opt = steps_lib.make_optimizer(tcfg)
            from repro.dist.sharding import infer_param_sharding
            ostate_shapes = jax.eval_shape(opt.init, pshapes)
            oshard = infer_param_sharding(ostate_shapes, mesh)
            ctx_shapes = steps_lib.round_ctx_specs(mesh)
            ctx_shard = jax.tree_util.tree_map(
                lambda l: NamedSharding(mesh, P()), ctx_shapes)
            fn = jax.jit(step,
                         in_shardings=(pshard, oshard, in_shard, ctx_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(pshapes, ostate_shapes, specs, ctx_shapes)
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(model)
            fn = jax.jit(step, in_shardings=(pshard, in_shard))
            lowered = fn.lower(pshapes, specs)
        else:  # decode
            step = steps_lib.make_decode_step(model)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cshard = steps_lib.cache_shardings(cache_shapes, mesh)
            tok = specs["tokens"]
            tok_shard = NamedSharding(
                mesh, best_spec(tok.shape, ["data", None], mesh))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(step, in_shardings=(pshard, cshard, tok_shard,
                                             NamedSharding(mesh, P())),
                         donate_argnums=(1,))
            lowered = fn.lower(pshapes, cache_shapes, tok, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # older jax returns one dict per program; normalise to a flat dict
    if cost and not isinstance(cost, dict):
        cost = cost[0]
    cost = cost or {}
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    n_dev = 512 if multi_pod else 256
    result = {
        "status": "ok",
        "variant": variant,
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "agg": agg if shape.kind == "train" else None,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem,
                                            "generated_code_size_in_bytes",
                                            None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals",
                  "optimal_seconds") if k in cost},
        "collectives": coll,
        "param_count": cfg.param_count(),
    }
    return result


def combo_path(arch, shape_name, mesh_tag, agg, variant="baseline"):
    suffix = "" if variant == "baseline" else f"__{variant}"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_tag}__{agg}{suffix}.json"


def run_combo(arch, shape_name, multi_pod, agg="obcsaa", force=False,
              variant="baseline"):
    mesh_tag = "multi" if multi_pod else "single"
    path = combo_path(arch, shape_name, mesh_tag, agg, variant)
    if path.exists() and not force:
        return json.loads(path.read_text())
    try:
        res = lower_combo(arch, shape_name, multi_pod=multi_pod, agg=agg,
                          variant=variant)
    except Exception as e:
        res = {"status": "error", "arch": arch, "shape": shape_name,
               "mesh": mesh_tag, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(res, indent=1, default=str))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--agg", default="obcsaa", choices=["obcsaa", "mean"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = "multi" if mp else "single"
                res = run_combo(arch, shape, mp, args.agg, force=args.force,
                                variant=args.variant)
                status = res["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile={res['compile_s']}s "
                             f"flops={res['cost'].get('flops', 0):.3e} "
                             f"coll={res['collectives']['total_bytes']:.3e}B")
                elif status == "error":
                    extra = res["error"][:160]
                else:
                    extra = res.get("reason", "")[:80]
                print(f"[{status:7s}] {arch:22s} {shape:12s} {tag:6s} {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
