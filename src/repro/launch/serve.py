"""Deprecated shim — ``repro.launch.serve`` is now
``repro.launch.decode_demo`` (it demos model prefill/decode, not a
serving system). The continuous scheduling service this name suggests
lives in ``repro.serve`` (DESIGN.md §15):

  PYTHONPATH=src python -m repro.serve --cells 10000 --ticks 20
"""
from __future__ import annotations

import warnings

from repro.launch.decode_demo import main  # noqa: F401

warnings.warn(
    "repro.launch.serve is deprecated: the prefill/decode demo moved to "
    "repro.launch.decode_demo, and the scheduling service lives in "
    "repro.serve (python -m repro.serve)", DeprecationWarning,
    stacklevel=2)

if __name__ == "__main__":
    main()
