"""Runnable trainer (example-scale on CPU; production mesh on TPU).

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 20 --agg obcsaa

Uses the same step builders as the dry-run; with --smoke the reduced config
trains on synthetic token streams over a host mesh.

``--serve`` hands the remaining arguments to the continuous scheduling
service instead (``repro.serve``, DESIGN.md §15):

  PYTHONPATH=src python -m repro.launch.train --serve --cells 10000
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.data import token_stream
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh, num_workers
from repro.models.registry import build_model


def make_batch(cfg, B, S, rng_seed=0):
    tokens, targets = token_stream(B, S, cfg.vocab_size, seed=rng_seed)
    batch = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(targets)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = 0.01 * jnp.ones(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


def make_zoo_batch(cfg, U, B, S, rng_seed=0):
    """(U, B, ...)-stacked per-worker batches for the zoo round: each of
    the mesh's U FL workers trains on its own token stream."""
    per = [make_batch(cfg, B, S, rng_seed=rng_seed * 1000 + u)
           for u in range(U)]
    return {k: jnp.stack([p[k] for p in per]) for k in per[0]}


def run_zoo_train(args, cfg, tcfg, model, mesh):
    """--zoo-train driver: real sharded backward passes through the
    chunked (n_chunks, D_c) round (engine.zoo_train, DESIGN.md §16/§17).

    The carry is the full ZooTrainState — master + optimizer moments +
    per-worker EF residuals — so --ckpt-dir/--resume restore mid-run with
    non-trivial optimizer state bit-for-bit. With --data, every round
    samples a fresh (U, B, S) batch from the memmapped token shards,
    keyed by the absolute round index (no iterator state to serialize)."""
    zr = steps_lib.make_zoo_train_round(model, tcfg, mesh)
    print(f"zoo-train: D={zr.D:,} n_chunks={zr.n_chunks} "
          f"({zr.n_model} model x {zr.U} workers x {zr.n_local} local), "
          f"optimizer={zr.optimizer_name} ef={zr.error_feedback} "
          f"remat={tcfg.remat_mode}", flush=True)
    params = model.init(jax.random.PRNGKey(0))
    master = zr.chunk_params(params)
    key = jax.random.PRNGKey(1)
    data_key = jax.random.PRNGKey(2)
    shards = None
    if args.data:
        from repro.data import TokenShards
        shards = TokenShards.open(args.data)
        print(f"data: {len(shards.names)} token shards, "
              f"{shards.total_tokens:,} tokens from {args.data}",
              flush=True)

    def zoo_batch(t):
        if shards is not None:
            return zr.shard_batch(shards.sample_zoo_batch(
                data_key, t, zr.U, args.batch, args.seq))
        return zr.shard_batch(
            make_zoo_batch(cfg, zr.U, args.batch, args.seq))

    if args.arms > 1:
        A = args.arms
        arms = {"noise_var": jnp.float32(tcfg.noise_var)
                * jnp.logspace(0, 2, A, dtype=jnp.float32),
                "p_max": jnp.full((A,), tcfg.p_max, jnp.float32),
                "lr": jnp.float32(args.lr)
                * jnp.logspace(0, -1, A, dtype=jnp.float32)}
        states = zr.shard_state(zr.init_sweep_state(
            jnp.broadcast_to(master, (A,) + master.shape)), arms=A)
        t_start = 0
        if args.resume:
            got = zr.restore_state(args.ckpt_dir, arms=A)
            if got is not None:
                states, t_start = got
                print(f"resumed sweep at round {t_start}", flush=True)
        batch = zoo_batch(t_start)   # sweeps run one fixed batch
        t0 = time.time()
        states, stats = zr.run_sweep(states, batch, arms,
                                     args.steps - t_start, key=key,
                                     t0=t_start)
        losses = np.asarray(stats.loss)          # (rounds, A)
        dt = time.time() - t0
        for a in range(A):
            print(f"arm {a}: noise_var={float(arms['noise_var'][a]):.2e} "
                  f"lr={float(arms['lr'][a]):.3f} "
                  f"loss {losses[0, a]:.4f} -> {losses[-1, a]:.4f}",
                  flush=True)
        print(f"{A} arms x {args.steps - t_start} rounds in one program "
              f"({dt:.2f}s)", flush=True)
        if args.ckpt_dir:
            path = zr.save_state(args.ckpt_dir, args.steps, states,
                                 t_next=args.steps)
            print(f"saved checkpoint: {path}")
    else:
        state = zr.shard_state(zr.init_state(master))
        t_start = 0
        if args.resume:
            got = zr.restore_state(args.ckpt_dir)
            if got is not None:
                state, t_start = got
                print(f"resumed zoo-train at round {t_start}", flush=True)
        batch = None
        for t in range(t_start, args.steps):
            if shards is not None or batch is None:
                batch = zoo_batch(t)
            t0 = time.time()
            state, st = zr.round_train(state, batch, t, key,
                                       tcfg.noise_var, tcfg.p_max,
                                       args.lr)
            print(f"round {t:4d} loss={float(st.loss):.4f} "
                  f"b_t={float(st.b_t):.4f} ({time.time()-t0:.2f}s)",
                  flush=True)
            if args.ckpt_dir and args.ckpt_every \
                    and (t + 1) % args.ckpt_every == 0:
                zr.save_state(args.ckpt_dir, t + 1, state, t_next=t + 1)
        if args.ckpt_dir:
            path = zr.save_state(args.ckpt_dir, args.steps, state,
                                 t_next=args.steps)
            print(f"saved checkpoint: {path}")


def main():
    if "--serve" in sys.argv[1:]:
        # dispatch to the scheduling-service CLI with the rest of the
        # arguments (repro.serve owns its own parser)
        from repro.serve.cli import main as serve_main
        argv = [a for a in sys.argv[1:] if a != "--serve"]
        raise SystemExit(serve_main(argv))
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--agg", default="obcsaa", choices=["mean", "obcsaa"])
    ap.add_argument("--zoo-train", action="store_true",
                    help="train through the chunked zoo round with REAL "
                         "sharded backward passes (engine.zoo_train, "
                         "DESIGN.md §16): master lives as the sharded-flat "
                         "(n_chunks, D_c) array, grads flow into the "
                         "packed 1-bit uplink with no full-D gather")
    ap.add_argument("--arms", type=int, default=1,
                    help="with --zoo-train: run an N-arm noise_var x lr "
                         "grid as ONE jitted scan-over-rounds program "
                         "(ZooTrainRound.run_sweep)")
    ap.add_argument("--remat-policy", default=None,
                    choices=["off", "full", "dots", "dots_no_batch"],
                    help="scan-body checkpoint policy "
                         "(TrainConfig.remat_policy)")
    ap.add_argument("--scan-rounds", type=int, default=0,
                    help="fuse N rounds per dispatch via the scan engine "
                         "(P2 pre-scheduled for the whole span in one "
                         "batched solver call; DESIGN.md §11)")
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--optimizer", default="sgd",
                    help="sgd | momentum | adam — moments live as sharded "
                         "(n_chunks, D_c) carries in the zoo round "
                         "(DESIGN.md §17)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="per-worker EF residual over the 1-bit uplink "
                         "(Stich et al.; DESIGN.md §11/§17). Needs "
                         "--agg obcsaa")
    ap.add_argument("--data", default=None,
                    help="token-shard directory (repro.data.TokenShards) "
                         "— with --zoo-train, each round samples a fresh "
                         "per-worker batch keyed by the absolute round "
                         "index; default: fixed synthetic streams")
    ap.add_argument("--cs-chunk", type=int, default=1024)
    ap.add_argument("--cs-measure", type=int, default=256)
    ap.add_argument("--cs-topk", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="also snapshot params+opt every N steps (0: only "
                         "the final step); scan mode snapshots at chunk "
                         "boundaries whenever --ckpt-dir is set")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest step from --ckpt-dir and "
                         "continue; round RNG/schedules index absolute "
                         "steps, so the result matches an uninterrupted "
                         "run (DESIGN.md §14)")
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    tcfg = TrainConfig(aggregation=args.agg, optimizer=args.optimizer,
                       learning_rate=args.lr,
                       error_feedback=args.error_feedback,
                       cs_chunk=args.cs_chunk,
                       cs_measure=args.cs_measure, cs_topk=args.cs_topk,
                       biht_iters=10, cs_packed=args.zoo_train,
                       remat_policy=args.remat_policy)
    model = build_model(cfg)
    if args.zoo_train:
        # NOTE: no ambient set_mesh — the zoo round owns its shard_map and
        # the model forward runs fully manual inside it (DESIGN.md §16)
        return run_zoo_train(args, cfg, tcfg, model, mesh)
    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt = steps_lib.make_optimizer(tcfg)
        opt_state = opt.init(params)
        t_start = 0
        if args.resume:
            restored = steps_lib.restore_train_state(args.ckpt_dir, model,
                                                     tcfg, mesh)
            if restored is not None:
                params, opt_state, t_start = restored
                print(f"resumed from step {t_start}", flush=True)
        batch = make_batch(cfg, args.batch, args.seq)
        if args.scan_rounds > 0:
            # scan engine: one dispatch per n-round chunk, channels +
            # schedules precomputed for the whole run in one batched P2
            # solve (DESIGN.md §11)
            n = args.scan_rounds
            D = sum(int(np.prod(l.shape))
                    for l in jax.tree_util.tree_leaves(params))
            span = steps_lib.make_scheduled_round_span(
                mesh, tcfg, D, args.steps)
            scan_steps = {}   # chunk length -> jitted program (full + tail)

            def run_chunk(t0_round, m):
                if m not in scan_steps:
                    scan_steps[m] = jax.jit(
                        steps_lib.make_scan_train_step(model, tcfg, mesh,
                                                       m),
                        donate_argnums=(0, 1))
                ctxs = jax.tree_util.tree_map(
                    lambda x: x[t0_round:t0_round + m], span)
                return scan_steps[m](params, opt_state, batch, ctxs)

            if t_start % n:
                raise SystemExit(
                    f"--resume step {t_start} does not land on a "
                    f"--scan-rounds {n} chunk boundary; rerun with the "
                    f"cadence the checkpoints were saved with")
            for t0_round in range(0, args.steps, n):
                m = min(n, args.steps - t0_round)
                if t0_round + m <= t_start:
                    continue
                t0 = time.time()
                params, opt_state, metrics = run_chunk(t0_round, m)
                loss = float(metrics["loss"][-1])
                print(f"rounds {t0_round:4d}..{t0_round + m - 1} "
                      f"loss={loss:.4f} ({time.time()-t0:.2f}s)",
                      flush=True)
                if args.ckpt_dir:
                    steps_lib.save_train_state(args.ckpt_dir, t0_round + m,
                                               params, opt_state)
        else:
            step = jax.jit(steps_lib.make_train_step(model, tcfg, mesh),
                           donate_argnums=(0, 1))
            for t in range(t_start, args.steps):
                ctx = steps_lib.default_round_ctx(mesh, seed=t)
                t0 = time.time()
                params, opt_state, metrics = step(params, opt_state,
                                                  batch, ctx)
                loss = float(metrics["loss"])
                print(f"step {t:4d} loss={loss:.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
                if args.ckpt_dir and args.ckpt_every \
                        and (t + 1) % args.ckpt_every == 0:
                    steps_lib.save_train_state(args.ckpt_dir, t + 1,
                                               params, opt_state)
        if args.ckpt_dir:
            path = steps_lib.save_train_state(args.ckpt_dir, args.steps,
                                              params, opt_state)
            print(f"saved checkpoint: {path}")


if __name__ == "__main__":
    main()
