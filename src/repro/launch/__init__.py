"""Launch layer: production mesh, train/serve step builders, dry-run driver.

NOTE: do not import ``repro.launch.dryrun`` at package level — it sets
XLA_FLAGS (512 host devices) at import for its own process.
"""
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               make_zoo_mesh, num_workers, worker_axes)

__all__ = ["make_host_mesh", "make_production_mesh", "make_zoo_mesh",
           "num_workers", "worker_axes"]
