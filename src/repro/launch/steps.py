"""Step builders: train (mean | obcsaa aggregation), prefill, decode.

The OBCSAA train step is the paper's technique as a first-class feature of
the distributed trainer: ``jax.shard_map`` manual over the worker axes
(pod, data) — each data-parallel shard IS an FL worker with a real local
gradient — and auto over ``model``, so GSPMD still lays out tensor-parallel
collectives inside the per-worker forward/backward (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.core.obcsaa import (OBCSAAConfig, shardmap_compress,
                               shardmap_reconstruct)
from repro.dist import collectives as coll
from repro.dist.sharding import best_spec, constrain, infer_param_sharding
from repro.launch.mesh import num_workers, worker_axes
from repro.models.registry import Model
from repro.models.transformer import cache_shardings_hints
from repro.optim.optimizers import Optimizer, make as make_opt


def make_optimizer(tcfg: TrainConfig) -> Optimizer:
    # one registry for the engine, the CLI, and the zoo-train carries
    # (repro.optim.OPTIMIZERS, DESIGN.md §17)
    return make_opt(tcfg.optimizer)


def obcsaa_config(tcfg: TrainConfig) -> OBCSAAConfig:
    return OBCSAAConfig(chunk=tcfg.cs_chunk, measure=tcfg.cs_measure,
                        topk=tcfg.cs_topk, biht_iters=tcfg.biht_iters,
                        decoder=tcfg.cs_decoder, recon_tau=tcfg.cs_tau,
                        noise_var=tcfg.noise_var, p_max=tcfg.p_max,
                        spmd_topk=True, packed=tcfg.cs_packed)


# --- batch shardings -------------------------------------------------------------

def batch_pspecs(batch_specs: Dict, mesh) -> Dict:
    """Shard the leading (global-batch) dim of every input over (pod, data)."""
    out = {}
    for k, v in batch_specs.items():
        hints = ["data"] + [None] * (len(v.shape) - 1)
        out[k] = best_spec(v.shape, hints, mesh)
    return out


# --- OBCSAA per-leaf gradient aggregation ------------------------------------------

def _shard_aligned_perm(leaf_shape, spec, model_axis="model"):
    """Permutation putting the model-sharded dim first (§Perf H1: makes the
    flatten->chunk reshape a LOCAL op — no gradient reshard before Φ)."""
    if spec is None:
        return None
    parts = list(spec) + [None] * (len(leaf_shape) - len(spec))
    for i, p in enumerate(parts):
        names = (p,) if isinstance(p, str) else (p or ())
        if model_axis in names:
            return (i,) + tuple(j for j in range(len(leaf_shape)) if j != i)
    return None


def _aggregate_leaf(ob: OBCSAAConfig, leaf, waxes, phi, *, k_weight, beta_i,
                    b_t, noise_key, wire_dtype=jnp.float32, perm=None):
    """Compress one gradient leaf on this worker, MAC-aggregate, decode."""
    inv_perm = None
    if perm is not None:
        import numpy as _np
        inv_perm = tuple(int(i) for i in _np.argsort(_np.asarray(perm)))
        leaf_t = leaf.transpose(perm)
    else:
        leaf_t = leaf
    flat = leaf_t.reshape(-1).astype(jnp.float32)
    D = flat.shape[0]
    rem = (-D) % ob.chunk
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), flat.dtype)])
    chunks = flat.reshape(-1, ob.chunk)
    chunks = constrain(chunks, ("model", None))
    y, ksum, mag_sum = shardmap_compress(ob, chunks, waxes, k_weight=k_weight,
                                         beta_i=beta_i, b_t=b_t, phi=phi,
                                         wire_dtype=wire_dtype)
    ghat = shardmap_reconstruct(ob, y, ksum, mag_sum, b_t=b_t,
                                noise_key=noise_key, phi=phi)
    out = ghat[:D].reshape(leaf_t.shape).astype(leaf.dtype)
    if inv_perm is not None:
        out = out.transpose(inv_perm)
    return out


def obcsaa_aggregate_tree(ob: OBCSAAConfig, grads, waxes, *, k_weight,
                          beta_i, b_t, noise_key, wire_dtype=jnp.float32,
                          specs=None):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(specs,
                                                is_leaf=lambda x: x is None)
        if len(spec_leaves) != len(leaves):
            spec_leaves = [None] * len(leaves)
    else:
        spec_leaves = [None] * len(leaves)
    phi = ob.phi()
    out = []
    for i, leaf in enumerate(leaves):
        key = jax.random.fold_in(noise_key, i)
        perm = (_shard_aligned_perm(leaf.shape, spec_leaves[i])
                if spec_leaves[i] is not None else None)
        out.append(_aggregate_leaf(ob, leaf, waxes, phi, k_weight=k_weight,
                                   beta_i=beta_i, b_t=b_t, noise_key=key,
                                   wire_dtype=wire_dtype, perm=perm))
    return jax.tree_util.tree_unflatten(treedef, out)


# --- train steps -------------------------------------------------------------------

def make_train_step(model: Model, tcfg: TrainConfig, mesh) -> Callable:
    """Returns step(params, opt_state, batch, round_ctx) ->
    (params, opt_state, metrics). round_ctx carries (h, beta, b_t, key)."""
    opt = make_optimizer(tcfg)
    waxes = worker_axes(mesh)
    U = num_workers(mesh)

    def loss_of(params, batch):
        loss, _ = model.loss_fn(params, batch, remat=tcfg.remat_mode)
        return loss

    if tcfg.aggregation == "mean":
        def step(params, opt_state, batch, round_ctx):
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            params, opt_state = opt.update(grads, opt_state, params,
                                           tcfg.learning_rate)
            return params, opt_state, {"loss": loss}

        return step

    ob = obcsaa_config(tcfg)
    wire_dtype = jnp.bfloat16 if tcfg.wire_dtype == "bfloat16" \
        else jnp.float32
    grad_specs = None
    if tcfg.cs_shard_aligned:
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = infer_param_sharding(pshapes, mesh)
        grad_specs = jax.tree_util.tree_map(lambda s: s.spec, shardings)

    def per_worker(params, batch, h_all, beta_all, b_t, noise_key):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        widx = jax.lax.axis_index(waxes)
        beta_i = beta_all[widx]
        k_weight = jnp.float32(1.0)                    # equal K_i shards
        ghat = obcsaa_aggregate_tree(ob, grads, waxes, k_weight=k_weight,
                                     beta_i=beta_i, b_t=b_t,
                                     noise_key=noise_key,
                                     wire_dtype=wire_dtype, specs=grad_specs)
        loss = coll.pmean(loss, waxes)
        return loss, ghat

    def step(params, opt_state, batch, round_ctx):
        # batch leaves all shard their leading dim over the worker axes
        bspec = P(waxes if len(waxes) > 1 else waxes[0])
        loss, ghat = jax.shard_map(
            per_worker, mesh=mesh, axis_names=set(waxes),
            in_specs=(P(), jax.tree_util.tree_map(lambda _: bspec, batch),
                      P(), P(), P(), P()),
            out_specs=(P(), P()),
            check_vma=False)(params, batch, round_ctx["h"],
                             round_ctx["beta"], round_ctx["b_t"],
                             round_ctx["key"])
        params, opt_state = opt.update(ghat, opt_state, params,
                                       tcfg.learning_rate)
        return params, opt_state, {"loss": loss}

    return step


def default_round_ctx(mesh, seed: int = 0):
    U = num_workers(mesh)
    return {"h": jnp.ones((U,), jnp.float32),
            "beta": jnp.ones((U,), jnp.float32),
            "b_t": jnp.float32(1.0),
            "key": jax.random.PRNGKey(seed)}


def round_ctx_specs(mesh):
    U = num_workers(mesh)
    import numpy as np
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return {"h": jax.ShapeDtypeStruct((U,), jnp.float32),
            "beta": jax.ShapeDtypeStruct((U,), jnp.float32),
            "b_t": jax.ShapeDtypeStruct((), jnp.float32),
            "key": key}


def make_scheduled_round_ctx(mesh, tcfg: TrainConfig, D: int, *,
                             scenario=None, method: str = "greedy_batched",
                             seed: int = 0):
    """P2-scheduled round contexts for the mesh train step (DESIGN.md §10).

    Pre-generates a time-correlated fading trajectory for the mesh's U
    workers (repro.sched.scenario) and returns ``round_ctx(t)``: each call
    slices round t's channels, solves P2 through the batched scheduler
    registry in one device call, and yields the {h, beta, b_t, key} dict
    the OBCSAA train step consumes — the device-resident replacement for
    ``default_round_ctx``'s everyone-scheduled stub. ``D`` is the model's
    flat parameter count (the R_t dimension term)."""
    from repro.theory.bounds import AnalysisConstants
    from repro.sched import SchedConfig, round_problems, schedule
    from repro.sched.scenario import ScenarioConfig, generate

    U = num_workers(mesh)
    scn = scenario or ScenarioConfig(rounds=256, cells=1, workers=U)
    assert scn.workers == U, (scn.workers, U)
    traj = generate(scn, jax.random.PRNGKey(seed))
    const = AnalysisConstants()
    cfg = SchedConfig()

    def round_ctx(t: int):
        prob = round_problems(traj, t % scn.rounds, k_weights=1.0,
                              p_max=tcfg.p_max, noise_var=tcfg.noise_var,
                              D=D, S=tcfg.cs_measure, kappa=tcfg.cs_topk,
                              const=const)
        beta, b_t, _ = schedule(prob, method, cfg)
        return {"h": traj[t % scn.rounds, 0],
                "beta": beta[0].astype(jnp.float32),
                "b_t": b_t[0].astype(jnp.float32),
                "key": jax.random.PRNGKey(seed * 100003 + t)}

    return round_ctx


def make_scheduled_round_span(mesh, tcfg: TrainConfig, D: int, rounds: int,
                              *, scenario=None,
                              method: str = "greedy_batched",
                              seed: int = 0) -> Dict:
    """Stacked round contexts for ``make_scan_train_step`` (DESIGN.md §11).

    Where ``make_scheduled_round_ctx`` solves P2 once per round on demand,
    this solves the WHOLE span in one batched registry call: the
    (rounds, U) fading trajectory becomes a B = rounds ``BatchedProblem``
    and the scheduler runs one device pass for every round's β/b_t. The
    returned dict has (rounds, ...)-leading leaves — the scan xs."""
    from repro.theory.bounds import AnalysisConstants
    from repro.sched import BatchedProblem, SchedConfig, schedule
    from repro.sched.scenario import ScenarioConfig, generate

    U = num_workers(mesh)
    scn = scenario or ScenarioConfig(rounds=rounds, cells=1, workers=U)
    assert scn.workers == U and scn.rounds >= rounds, (scn, U, rounds)
    traj = generate(scn, jax.random.PRNGKey(seed))
    h = traj[:rounds, 0]                                  # (rounds, U)
    prob = BatchedProblem.from_arrays(
        h, 1.0, tcfg.p_max, tcfg.noise_var, D=D, S=tcfg.cs_measure,
        kappa=tcfg.cs_topk, const=AnalysisConstants())
    beta, b_t, _ = schedule(prob, method, SchedConfig())
    keys = jax.vmap(
        lambda t: jax.random.fold_in(jax.random.PRNGKey(seed * 100003), t)
    )(jnp.arange(rounds))
    return {"h": h, "beta": beta.astype(jnp.float32),
            "b_t": b_t.astype(jnp.float32), "key": keys}


def make_scan_train_step(model: Model, tcfg: TrainConfig, mesh,
                         n_rounds: int) -> Callable:
    """Multi-round train step: ``lax.scan`` of the per-round step over
    stacked round contexts (DESIGN.md §11) — one jit dispatch advances
    ``n_rounds`` rounds of the mesh trainer, with the per-round
    ``shard_map`` OBCSAA aggregation (or mean) inlined in the scan body.

    Returns ``scan_step(params, opt_state, batch, round_ctxs)`` where
    ``round_ctxs`` comes from ``make_scheduled_round_span`` (or any dict
    of (n_rounds, ...)-leading arrays shaped like ``default_round_ctx``).
    """
    step = make_train_step(model, tcfg, mesh)

    def scan_step(params, opt_state, batch, round_ctxs):
        def body(carry, ctx):
            params, opt_state = carry
            params, opt_state, metrics = step(params, opt_state, batch,
                                              ctx)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            body, (params, opt_state), round_ctxs, length=n_rounds)
        return params, opt_state, metrics

    return scan_step


# --- zoo-scale real-gradient rounds (DESIGN.md §16) -------------------------------

def make_zoo_train_round(model: Model, tcfg: TrainConfig, mesh, **kw):
    """The sharded real-backward zoo round for (model, tcfg, mesh).

    Builds :class:`repro.engine.zoo_train.ZooTrainRound` from the SAME
    TrainConfig knobs the per-leaf OBCSAA train step consumes —
    ``obcsaa_config(tcfg)`` for the wire geometry (including the packed
    uplink), ``tcfg.remat_mode`` for the scan-body checkpointing policy —
    so a config that trains through ``make_train_step`` sweeps through
    the chunked zoo round unchanged. Extra kwargs (``scheduler``,
    ``compute_dtype``, ``block_chunks``, ...) pass through."""
    from repro.engine.zoo_train import ZooTrainRound
    kw.setdefault("remat", tcfg.remat_mode)
    kw.setdefault("optimizer", tcfg.optimizer)
    kw.setdefault("error_feedback", tcfg.error_feedback)
    return ZooTrainRound(model, mesh, obcsaa_config(tcfg), **kw)


# --- serve steps -------------------------------------------------------------------

def make_prefill_step(model: Model) -> Callable:
    def step(params, batch):
        logits, cache = model.prefill(params, batch)
        return logits, cache

    return step


def make_decode_step(model: Model) -> Callable:
    def step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return step


def make_seeded_prefill(model: Model, total_len: int) -> Callable:
    """Prefill a prompt prefix and seed a ``total_len`` decode cache.

    Returns ``step(params, batch) -> (logits, cache, offset)``: the
    prefix (vlm image embeds + any prompt tokens) runs through the full
    forward once, its per-layer KV seeds land in slots [0, offset) of a
    fresh cache, and decoding continues at ``pos = offset + i``. This is
    how vlm serving consumes the image prefix — decode steps are
    text-only, so the image context must enter through the cache."""
    from repro.models import transformer

    cfg = model.cfg

    def step(params, batch):
        logits, seeds = model.prefill(params, batch)
        img = batch.get("image_embeds")
        offset = batch["tokens"].shape[1] + (
            img.shape[1] if img is not None else 0)
        cache = model.init_cache(batch["tokens"].shape[0], total_len)
        cache = transformer.seed_cache_from_prefill(cfg, cache, seeds,
                                                    start=0)
        return logits, cache, offset

    return step


def cache_shardings(cache_shapes, mesh):
    """NamedShardings for a cache pytree (dict of arrays) via dim hints."""
    hints = cache_shardings_hints()
    hints.update({"cross_k": hints["k"], "cross_v": hints["v"]})

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        h = hints.get(name, (None,) * len(leaf.shape))
        return NamedSharding(mesh, best_spec(leaf.shape, h, mesh))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


def param_shardings(model: Model, mesh, sample_batch_specs=None):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return infer_param_sharding(shapes, mesh), shapes


# --- trainer checkpointing (DESIGN.md §14) ---------------------------------------

def save_train_state(ckpt_dir: str, step: int, params, opt_state) -> str:
    """Snapshot the trainer carry (params + optimizer state) at ``step``.
    One atomic step directory via ``repro.checkpoint.save``."""
    from repro import checkpoint
    return checkpoint.save(ckpt_dir, step,
                           {"params": params, "opt_state": opt_state})


def restore_train_state(ckpt_dir: str, model: Model, tcfg: TrainConfig,
                        mesh):
    """(params, opt_state, step) from the latest checkpoint, placed with
    the mesh shardings the train step expects — the checkpoint itself is
    geometry-free (plain arrays), so a run saved on one mesh restores onto
    a differently-sized one (DESIGN.md §14). Returns None when
    ``ckpt_dir`` holds no steps yet (fresh start)."""
    from repro import checkpoint
    step = checkpoint.latest_step(ckpt_dir)
    if step is None:
        return None
    pshard, pshapes = param_shardings(model, mesh)
    oshapes = jax.eval_shape(make_optimizer(tcfg).init, pshapes)
    tree = checkpoint.restore(
        ckpt_dir, step, {"params": pshapes, "opt_state": oshapes},
        shardings={"params": pshard,
                   "opt_state": infer_param_sharding(oshapes, mesh)})
    return tree["params"], tree["opt_state"], step
