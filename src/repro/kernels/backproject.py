"""BIHT back-projection update kernel: x' = x + τ · r @ Φ.

r: (n, S) residual, Φ: (S, D); the add into x is fused into the matmul
epilogue (x tile read once, written once).

``backproject_packed`` is the packed-codec variant (DESIGN.md §13): the
BIHT residual arrives as the two uint32 bit-planes (plus, minus) emitted by
``cs_project(mode="pack_sign_residual")`` and is unpacked INSIDE the kernel
to resid = 2·(plus − minus) ∈ {−2, 0, +2} — exactly the f32 values
``y − sign(Φx)`` takes on ±1 measurements, so the identical ``dot_general``
makes the packed loop bit-for-bit equal to the f32 loop while moving 1/16
of the residual bytes through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sign import PACK, unpack_bits

BN = 128
BD = 256
BS = 256   # contraction tile over S


def _validate(name, n, s, d, bn, bd, bs, *, packed=False):
    if n % bn or d % bd or s % bs:
        raise ValueError(
            f"{name}: shapes (n={n}, S={s}, D={d}) do not tile by "
            f"(bn={bn}, bd={bd}, bs={bs}); pad n to a row-tile multiple "
            f"(the ops.py wrappers do) or pass tiles= (DESIGN.md §13).")
    if packed and (s % PACK or bs % PACK):
        raise ValueError(
            f"{name}: packed residual needs S and the S-tile to be "
            f"multiples of {PACK}; got S={s}, bs={bs} (DESIGN.md §13).")


def _backproject_kernel(r_ref, phi_ref, x_ref, out_ref, acc_ref, *, n_bs,
                        tau):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        r_ref[...], phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bs - 1)
    def _():
        out_ref[...] = (x_ref[...].astype(jnp.float32)
                        + tau * acc_ref[...]).astype(out_ref.dtype)


def _backproject_packed_kernel(plus_ref, minus_ref, phi_ref, x_ref, out_ref,
                               acc_ref, *, n_bs, tau):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack the residual bit-planes in-VMEM: 2·(plus − minus) reproduces
    # the exact {−2, 0, +2} floats of the f32 residual tile
    resid = 2.0 * (unpack_bits(plus_ref[...], jnp.float32)
                   - unpack_bits(minus_ref[...], jnp.float32))
    acc_ref[...] += jax.lax.dot_general(
        resid, phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bs - 1)
    def _():
        out_ref[...] = (x_ref[...].astype(jnp.float32)
                        + tau * acc_ref[...]).astype(out_ref.dtype)


def backproject(x: jnp.ndarray, resid: jnp.ndarray, phi: jnp.ndarray,
                tau: float, *, interpret: bool = False,
                tiles=None) -> jnp.ndarray:
    """x: (n, D); resid: (n, S); phi: (S, D) -> x + tau * resid @ phi.

    ``tiles=(bn, bd, bs)`` overrides the default VMEM tiling (see
    cs_project.project; the fused decode loop passes full-extent tiles in
    interpret mode for bit-parity with the einsum reference)."""
    n, d = x.shape
    s = phi.shape[0]
    if resid.shape != (n, s) or phi.shape != (s, d):
        raise ValueError(f"backproject: resid {resid.shape} / phi "
                         f"{phi.shape} inconsistent with x {x.shape}")
    bn, bd, bs = tiles if tiles else (min(BN, n), min(BD, d), min(BS, s))
    _validate("backproject", n, s, d, bn, bd, bs)
    n_bs = s // bs
    grid = (n // bn, d // bd, n_bs)
    return pl.pallas_call(
        functools.partial(_backproject_kernel, n_bs=n_bs, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bs), lambda i, j, k: (i, k)),   # resid
            pl.BlockSpec((bs, bd), lambda i, j, k: (k, j)),   # phi
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),   # x
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        interpret=interpret,
    )(resid, phi, x)


def backproject_packed(x: jnp.ndarray, plus: jnp.ndarray, minus: jnp.ndarray,
                       phi: jnp.ndarray, tau: float, *,
                       interpret: bool = False, tiles=None) -> jnp.ndarray:
    """Packed-residual update: x + tau * (2·(plus − minus)) @ phi.

    plus/minus: uint32 (n, S//32) bit-planes from
    ``cs_project(mode="pack_sign_residual")``; unpacked in-tile
    (DESIGN.md §13). Bit-for-bit equal to ``backproject`` on the
    equivalent f32 residual under the same tiling."""
    n, d = x.shape
    s = phi.shape[0]
    if phi.shape != (s, d):
        raise ValueError(f"backproject_packed: phi {phi.shape} inconsistent "
                         f"with x {x.shape}")
    if plus.shape != (n, s // PACK) or minus.shape != (n, s // PACK) \
            or plus.dtype != jnp.uint32 or minus.dtype != jnp.uint32:
        raise ValueError(
            f"backproject_packed: bit-planes must be uint32 "
            f"(n, S//{PACK}) = ({n}, {s // PACK}); got {plus.dtype} "
            f"{plus.shape} / {minus.dtype} {minus.shape} (DESIGN.md §13)")
    bn, bd, bs = tiles if tiles else (min(BN, n), min(BD, d), min(BS, s))
    _validate("backproject_packed", n, s, d, bn, bd, bs, packed=True)
    n_bs = s // bs
    grid = (n // bn, d // bd, n_bs)
    return pl.pallas_call(
        functools.partial(_backproject_packed_kernel, n_bs=n_bs, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, k)),  # plus
            pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, k)),  # minus
            pl.BlockSpec((bs, bd), lambda i, j, k: (k, j)),          # phi
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),          # x
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        interpret=interpret,
    )(plus, minus, phi, x)
