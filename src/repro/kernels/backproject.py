"""BIHT back-projection update kernel: x' = x + τ · r @ Φ.

r: (n, S) residual, Φ: (S, D); the add into x is fused into the matmul
epilogue (x tile read once, written once).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BN = 128
BD = 256
BS = 256   # contraction tile over S


def _backproject_kernel(r_ref, phi_ref, x_ref, out_ref, acc_ref, *, n_bs,
                        tau):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        r_ref[...], phi_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bs - 1)
    def _():
        out_ref[...] = (x_ref[...].astype(jnp.float32)
                        + tau * acc_ref[...]).astype(out_ref.dtype)


def backproject(x: jnp.ndarray, resid: jnp.ndarray, phi: jnp.ndarray,
                tau: float, *, interpret: bool = False,
                tiles=None) -> jnp.ndarray:
    """x: (n, D); resid: (n, S); phi: (S, D) -> x + tau * resid @ phi.

    ``tiles=(bn, bd, bs)`` overrides the default VMEM tiling (see
    cs_project.project; the fused decode loop passes full-extent tiles in
    interpret mode for bit-parity with the einsum reference)."""
    n, d = x.shape
    s = phi.shape[0]
    assert resid.shape == (n, s) and phi.shape == (s, d)
    bn, bd, bs = tiles if tiles else (min(BN, n), min(BD, d), min(BS, s))
    assert n % bn == 0 and d % bd == 0 and s % bs == 0, \
        f"shapes ({n},{s},{d}) not tileable by ({bn},{bs},{bd})"
    n_bs = s // bs
    grid = (n // bn, d // bd, n_bs)
    return pl.pallas_call(
        functools.partial(_backproject_kernel, n_bs=n_bs, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bs), lambda i, j, k: (i, k)),   # resid
            pl.BlockSpec((bs, bd), lambda i, j, k: (k, j)),   # phi
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),   # x
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        interpret=interpret,
    )(resid, phi, x)
