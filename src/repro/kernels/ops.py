"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` — the kernel body
executes in Python/XLA for correctness validation; on TPU the same code lowers
to Mosaic. Wrappers pad inputs up to tile multiples and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backproject as _bp
from repro.kernels import cs_project as _cs
from repro.kernels import topk_select as _tk
from repro.kernels import ref as _ref
from repro.kernels import sign as sign_codec


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, mult):
    n = x.shape[0]
    rem = (-n) % mult
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,) + x.shape[1:], x.dtype)])
    return x, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def cs_project_sign(phi, chunks, interpret=None):
    """sign(chunks @ phiᵀ): phi (S, D), chunks (n, D) -> (n, S)."""
    interpret = _interpret() if interpret is None else interpret
    chunks, n = _pad_rows(chunks, min(_cs.BN, max(1, chunks.shape[0])))
    out = _cs.project(phi, chunks, mode="sign", interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cs_project_pack(phi, chunks, interpret=None):
    """Fused sign+pack compression (DESIGN.md §13): phi (S, D),
    chunks (n, D) -> uint32 (n, S//32); bit = 1 ⇔ projection >= 0.

    Unpacking the result reproduces ``cs_project_sign`` bit for bit —
    both epilogues share the one sign predicate (kernels/sign.py)."""
    interpret = _interpret() if interpret is None else interpret
    chunks, n = _pad_rows(chunks, min(_cs.BN, max(1, chunks.shape[0])))
    return _cs.project(phi, chunks, mode="pack", interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cs_pack_sign_residual(phi, x, y_packed, interpret=None):
    """Packed BIHT residual planes (DESIGN.md §13): the fresh sign(x Φᵀ)
    is consumed in-kernel; returns (plus, minus) uint32 (n, S//32) with
    resid = 2·(plus − minus)."""
    interpret = _interpret() if interpret is None else interpret
    bn = min(_cs.BN, max(1, x.shape[0]))
    x, n = _pad_rows(x, bn)
    y_packed, _ = _pad_rows(y_packed, bn)
    plus, minus = _cs.project(phi, x, mode="pack_sign_residual", y=y_packed,
                              interpret=interpret)
    return plus[:n], minus[:n]


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def backproject_packed(x, plus, minus, phi, tau, interpret=None):
    """x + tau * (2·(plus − minus)) @ phi with the bit-planes unpacked
    in-tile (DESIGN.md §13)."""
    interpret = _interpret() if interpret is None else interpret
    bn = min(_bp.BN, max(1, x.shape[0]))
    x, n = _pad_rows(x, bn)
    plus, _ = _pad_rows(plus, bn)
    minus, _ = _pad_rows(minus, bn)
    return _bp.backproject_packed(x, plus, minus, phi, tau,
                                  interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cs_project(phi, chunks, interpret=None):
    interpret = _interpret() if interpret is None else interpret
    chunks, n = _pad_rows(chunks, min(_cs.BN, max(1, chunks.shape[0])))
    return _cs.project(phi, chunks, mode="none", interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select(chunks, k, interpret=None):
    """Per-row top-k by magnitude -> (values, mask)."""
    interpret = _interpret() if interpret is None else interpret
    chunks, n = _pad_rows(chunks, min(_tk.BN, max(1, chunks.shape[0])))
    val, mask = _tk.topk_select(chunks, k, interpret=interpret)
    return val[:n], mask[:n]


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def backproject(x, resid, phi, tau, interpret=None):
    interpret = _interpret() if interpret is None else interpret
    bn = min(_bp.BN, max(1, x.shape[0]))
    x, n = _pad_rows(x, bn)
    resid, _ = _pad_rows(resid, bn)
    return _bp.backproject(x, resid, phi, tau, interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("k", "iters", "tau", "interpret"))
def biht(y, phi, k, iters, tau, interpret=None):
    """Full BIHT decode composed from the three kernels.

    y: (n, S) aggregated measurements; phi: (S, D). Unit-norm rows out."""
    interpret = _interpret() if interpret is None else interpret
    S = phi.shape[0]
    x0 = backproject(jnp.zeros((y.shape[0], phi.shape[1]), y.dtype), y, phi,
                     1.0 / S, interpret=interpret)
    x, _ = topk_select(x0, k, interpret=interpret)

    def step(x, _):
        resid = _cs_sign_residual(phi, x, y, interpret)
        x = backproject(x, resid, phi, tau / S, interpret=interpret)
        x, _ = topk_select(x, k, interpret=interpret)
        return x, None

    x, _ = jax.lax.scan(step, x, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)


def _cs_sign_residual(phi, x, y, interpret):
    bn = min(_cs.BN, max(1, x.shape[0]))
    x, n = _pad_rows(x, bn)
    y, _ = _pad_rows(y, bn)
    return _cs.project(phi, x, mode="sign_residual", y=y,
                       interpret=interpret)[:n]


# re-export oracles for tests
ref = _ref
