"""Fused Φ-projection + 1-bit sign kernel (the OBCSAA compression hot spot).

Computes sign(chunks @ Φᵀ) with MXU-aligned VMEM tiles. The sign epilogue is
fused into the final accumulation step, so on TPU the dense (n, S) projection
never round-trips HBM — only the ±1 symbols are written out.

Variants (shared kernel body, different epilogues):
- ``mode="sign"``:           sign(x Φᵀ)           (eq. 7 compression)
- ``mode="pack"``:           pack32(sign(x Φᵀ))   (packed codec, uint32 out)
- ``mode="sign_residual"``:  y − sign(x Φᵀ)       (BIHT residual step)
- ``mode="pack_sign_residual"``: the BIHT residual as TWO packed uint32
  bit-planes (plus, minus) with resid = 2·(plus − minus) — y arrives packed,
  the fresh signs are consumed in-kernel, and only 1/16 of the f32 residual
  bytes leave for the back-projection (DESIGN.md §13)
- ``mode="residual"``:       y − x Φᵀ             (IHT residual step, eq. 43)
- ``mode="none"``:           x Φᵀ                 (plain projection)

The residual epilogues are the decode-loop fusion boundary (DESIGN.md §9):
the dense (n, S) projection is consumed inside the kernel and never
round-trips HBM — only the residual leaves. sign(0) comes from the one
shared predicate in ``kernels/sign.py`` (DESIGN.md §13): the packed and f32
epilogues share ``acc >= 0``, which is what makes them bit-for-bit
interchangeable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sign import PACK, pack_bool, sign_pm1, unpack_bits

BN = 128   # chunk-rows per tile (MXU sublane-aligned)
BS = 128   # measurement rows per tile (lane-aligned)
BD = 512   # contraction tile: BN*BD + BS*BD + BN*BS f32 ≈ 0.6 MB VMEM

MODES = ("sign", "pack", "sign_residual", "pack_sign_residual", "residual",
         "none")
_PACKED_MODES = ("pack", "pack_sign_residual")
_Y_MODES = ("sign_residual", "pack_sign_residual", "residual")


def _epilogue(acc, mode, y_blk, dtype):
    if mode == "sign":
        return sign_pm1(acc).astype(dtype)
    if mode == "sign_residual":
        sgn = sign_pm1(acc)
        return (y_blk.astype(jnp.float32) - sgn).astype(dtype)
    if mode == "residual":
        return (y_blk.astype(jnp.float32) - acc).astype(dtype)
    return acc.astype(dtype)


def _proj_kernel(x_ref, phi_ref, out_ref, acc_ref, *, n_bd, mode):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], phi_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bd - 1)
    def _():
        if mode == "pack":
            # fused sign+pack: same `acc >= 0` predicate as mode="sign",
            # 32 lanes per uint32 word (DESIGN.md §13)
            out_ref[...] = pack_bool(acc_ref[...] >= 0)
        else:
            out_ref[...] = _epilogue(acc_ref[...], mode, None, out_ref.dtype)


def _proj_resid_kernel(x_ref, phi_ref, y_ref, out_ref, acc_ref, *, n_bd,
                       mode):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], phi_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bd - 1)
    def _():
        out_ref[...] = _epilogue(acc_ref[...], mode, y_ref[...],
                                 out_ref.dtype)


def _proj_pack_resid_kernel(x_ref, phi_ref, y_ref, plus_ref, minus_ref,
                            acc_ref, *, n_bd):
    """Packed BIHT residual: y packed in, (plus, minus) bit-planes out.

    resid = y − sign(x Φᵀ) ∈ {−2, 0, +2} when y is ±1; plus marks the +2
    lanes (y=+1, sign=−1), minus the −2 lanes. The fresh sign vector is
    consumed in-VMEM — it never exists in HBM in any dtype."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], phi_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bd - 1)
    def _():
        sb = acc_ref[...] >= 0                       # shared sign predicate
        yb = unpack_bits(y_ref[...], jnp.bool_)
        plus_ref[...] = pack_bool(yb & ~sb)
        minus_ref[...] = pack_bool(sb & ~yb)


def validate_tiling(name: str, n: int, s: int, d: int, bn: int, bs: int,
                    bd: int, *, packed: bool = False):
    """Explicit shape/tile validation (DESIGN.md §13) — a silent mis-tile
    would corrupt output blocks, and a packed word straddling a tile edge
    would corrupt 32 lanes at once, so both are hard errors."""
    if n % bn or s % bs or d % bd:
        raise ValueError(
            f"{name}: shapes (n={n}, S={s}, D={d}) do not tile by "
            f"(bn={bn}, bs={bs}, bd={bd}). Pad n to a row-tile multiple "
            f"(the ops.py wrappers do), keep S and D multiples of the "
            f"module tiles, or pass tiles= explicitly (DESIGN.md §13).")
    if packed and (s % PACK or bs % PACK):
        raise ValueError(
            f"{name}: packed codec needs S and the S-tile to be multiples "
            f"of {PACK} (32 signs per uint32 word); got S={s}, bs={bs} "
            f"(DESIGN.md §13).")


def project(phi: jnp.ndarray, chunks: jnp.ndarray, *, mode: str = "sign",
            y: jnp.ndarray = None, interpret: bool = False,
            tiles=None):
    """phi: (S, D); chunks: (n, D); returns (n, S) — except the packed
    modes: ``mode="pack"`` returns uint32 (n, S//32) and
    ``mode="pack_sign_residual"`` (packed ±1 ``y`` in) returns the two
    uint32 bit-planes ``(plus, minus)``, each (n, S//32).

    Shapes must tile by (BN, BS, BD) after the ops.py wrapper's padding —
    validated with an explicit error, never silently mis-tiled.
    ``tiles=(bn, bs, bd)`` overrides the default VMEM tiling — the fused
    decode loop (repro.decode.fused) passes full-extent contraction tiles in
    interpret mode so the single in-kernel dot matches the einsum reference
    bit for bit (DESIGN.md §9)."""
    if mode not in MODES:
        raise ValueError(f"cs_project: unknown mode {mode!r}; one of "
                         f"{MODES} (DESIGN.md §13)")
    n, d = chunks.shape
    s = phi.shape[0]
    if phi.shape[1] != d:
        raise ValueError(f"cs_project: phi {phi.shape} does not contract "
                         f"with chunks {chunks.shape} (need phi (S, D))")
    packed = mode in _PACKED_MODES
    bn, bs, bd = tiles if tiles else (min(BN, n), min(BS, s), min(BD, d))
    validate_tiling("cs_project", n, s, d, bn, bs, bd, packed=packed)
    if mode in _Y_MODES and y is None:
        raise ValueError(f"cs_project: mode {mode!r} needs y")
    n_bd = d // bd
    grid = (n // bn, s // bs, n_bd)
    in_specs = [
        pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),   # chunks
        pl.BlockSpec((bs, bd), lambda i, j, k: (j, k)),   # phi
    ]
    args = [chunks, phi]
    if mode == "pack_sign_residual":
        if y.dtype != jnp.uint32 or y.shape != (n, s // PACK):
            raise ValueError(
                f"cs_project: pack_sign_residual needs packed y uint32 "
                f"(n, S//{PACK}) = ({n}, {s // PACK}); got {y.dtype} "
                f"{y.shape} (DESIGN.md §13)")
        in_specs.append(
            pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, j)))
        args.append(y)
        return pl.pallas_call(
            functools.partial(_proj_pack_resid_kernel, n_bd=n_bd),
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, j)),
                pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n, s // PACK), jnp.uint32),
                jax.ShapeDtypeStruct((n, s // PACK), jnp.uint32),
            ],
            scratch_shapes=[pltpu.VMEM((bn, bs), jnp.float32)],
            interpret=interpret,
        )(*args)
    if mode in ("sign_residual", "residual"):
        in_specs.append(pl.BlockSpec((bn, bs), lambda i, j, k: (i, j)))
        args.append(y)
        kernel = functools.partial(_proj_resid_kernel, n_bd=n_bd, mode=mode)
    else:
        kernel = functools.partial(_proj_kernel, n_bd=n_bd, mode=mode)
    if mode == "pack":
        out_specs = pl.BlockSpec((bn, bs // PACK), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((n, s // PACK), jnp.uint32)
    else:
        out_specs = pl.BlockSpec((bn, bs), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((n, s), chunks.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bn, bs), jnp.float32)],
        interpret=interpret,
    )(*args)
