"""Fused Φ-projection + 1-bit sign kernel (the OBCSAA compression hot spot).

Computes sign(chunks @ Φᵀ) with MXU-aligned VMEM tiles. The sign epilogue is
fused into the final accumulation step, so on TPU the dense (n, S) projection
never round-trips HBM — only the ±1 symbols are written out.

Variants (shared kernel body, different epilogues):
- ``mode="sign"``:           sign(x Φᵀ)           (eq. 7 compression)
- ``mode="sign_residual"``:  y − sign(x Φᵀ)       (BIHT residual step)
- ``mode="residual"``:       y − x Φᵀ             (IHT residual step, eq. 43)
- ``mode="none"``:           x Φᵀ                 (plain projection)

The residual epilogues are the decode-loop fusion boundary (DESIGN.md §9):
the dense (n, S) projection is consumed inside the kernel and never
round-trips HBM — only the residual leaves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BN = 128   # chunk-rows per tile (MXU sublane-aligned)
BS = 128   # measurement rows per tile (lane-aligned)
BD = 512   # contraction tile: BN*BD + BS*BD + BN*BS f32 ≈ 0.6 MB VMEM


def _epilogue(acc, mode, y_blk, dtype):
    if mode == "sign":
        return jnp.where(acc >= 0, 1.0, -1.0).astype(dtype)
    if mode == "sign_residual":
        sgn = jnp.where(acc >= 0, 1.0, -1.0)
        return (y_blk.astype(jnp.float32) - sgn).astype(dtype)
    if mode == "residual":
        return (y_blk.astype(jnp.float32) - acc).astype(dtype)
    return acc.astype(dtype)


def _proj_kernel(x_ref, phi_ref, out_ref, acc_ref, *, n_bd, mode):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], phi_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bd - 1)
    def _():
        out_ref[...] = _epilogue(acc_ref[...], mode, None, out_ref.dtype)


def _proj_resid_kernel(x_ref, phi_ref, y_ref, out_ref, acc_ref, *, n_bd,
                       mode):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], phi_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_bd - 1)
    def _():
        out_ref[...] = _epilogue(acc_ref[...], mode, y_ref[...],
                                 out_ref.dtype)


def project(phi: jnp.ndarray, chunks: jnp.ndarray, *, mode: str = "sign",
            y: jnp.ndarray = None, interpret: bool = False,
            tiles=None) -> jnp.ndarray:
    """phi: (S, D); chunks: (n, D); returns (n, S).

    Shapes must tile by (BN, BS, BD) after the ops.py wrapper's padding.
    ``tiles=(bn, bs, bd)`` overrides the default VMEM tiling — the fused
    decode loop (repro.decode.fused) passes full-extent contraction tiles in
    interpret mode so the single in-kernel dot matches the einsum reference
    bit for bit (DESIGN.md §9)."""
    n, d = chunks.shape
    s = phi.shape[0]
    assert phi.shape[1] == d, (phi.shape, chunks.shape)
    bn, bs, bd = tiles if tiles else (min(BN, n), min(BS, s), min(BD, d))
    assert n % bn == 0 and s % bs == 0 and d % bd == 0, \
        f"shapes ({n},{s},{d}) not tileable by ({bn},{bs},{bd})"
    n_bd = d // bd
    grid = (n // bn, s // bs, n_bd)
    in_specs = [
        pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),   # chunks
        pl.BlockSpec((bs, bd), lambda i, j, k: (j, k)),   # phi
    ]
    args = [chunks, phi]
    if mode in ("sign_residual", "residual"):
        in_specs.append(pl.BlockSpec((bn, bs), lambda i, j, k: (i, j)))
        args.append(y)
        kernel = functools.partial(_proj_resid_kernel, n_bd=n_bd, mode=mode)
    else:
        kernel = functools.partial(_proj_kernel, n_bd=n_bd, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, s), chunks.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bs), jnp.float32)],
        interpret=interpret,
    )(*args)
