"""Greedy prefix-evaluation sweep kernel (P2 scheduling, DESIGN.md §10).

Evaluates R_t for every prefix of the channel-cap ordering — the inner
sweep of the vectorized greedy scheduler — from the sufficient-statistic
form: R depends on a prefix only through its length s1, its weight mass
s2 = ΣK_i (a running cumulative sum) and its min-cap b (the prefix's last
element under the descending sort). Sort-free and segmented: the sort
stays outside (jnp ``argsort``); the kernel tiles the sorted (B, U) arrays
over U and carries the running ΣK between grid steps in VMEM scratch, so
U ≥ 8192 sweeps stream through without materialising anything but the
(B, U) prefix-R output.

Per-batch-row scalar coefficients arrive packed as a (B, 8) f32 matrix
(``pack order: Ktot, ρ1, A, E, N``; see ``prefix_rt``) so one BlockSpec
feeds every tile. In interpret mode the default tile spans the full U
extent, making the in-kernel cumsum + formula the *same ops* as the jnp
reference path — bit-for-bit parity (tests/test_sched.py), mirroring the
fused-decode tiling policy of DESIGN.md §9.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BB = 8        # batch rows per tile
BU = 512      # prefix positions per tile (lane-aligned)
N_COEF = 8    # packed per-row scalar coefficients (5 used, lane padding)


def prefix_rt(s1, s2, b, *, ktot, rho1, A, E, N):
    """R_t from the prefix sufficient statistics (eq. 24 regrouped):

        R(s1, s2, b) = ρ1 (Ktot − s2)/Ktot + A + N/(s2·b)² + s1·E

    Shared verbatim by the jnp sweep, the batched flip-polish and this
    kernel — identical op order is what makes the full-extent interpret
    tile bit-for-bit with the jnp path (DESIGN.md §10)."""
    return rho1 * (ktot - s2) / ktot + A + N / (s2 * b) ** 2 + s1 * E


def _prefix_kernel(caps_ref, k_ref, coef_ref, out_ref, s2_ref, *, bu):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        s2_ref[...] = jnp.zeros_like(s2_ref)

    k = k_ref[...].astype(jnp.float32)                  # (bb, bu)
    s2 = s2_ref[...] + jnp.cumsum(k, axis=-1)
    base = (j * bu + 1).astype(jnp.float32)
    s1 = jax.lax.broadcasted_iota(jnp.float32, k.shape, 1) + base
    coef = coef_ref[...]
    out_ref[...] = prefix_rt(
        s1, s2, caps_ref[...].astype(jnp.float32),
        ktot=coef[:, 0:1], rho1=coef[:, 1:2], A=coef[:, 2:3],
        E=coef[:, 3:4], N=coef[:, 4:5]).astype(out_ref.dtype)
    s2_ref[...] = s2[:, -1:]


def prefix_eval(caps_sorted: jnp.ndarray, k_sorted: jnp.ndarray,
                coefs: jnp.ndarray, *, interpret: bool = False,
                tiles=None) -> jnp.ndarray:
    """caps_sorted, k_sorted: (B, U) descending-cap order; coefs: (B, 8)
    packed [Ktot, ρ1, A, E, N, 0, 0, 0]. Returns the (B, U) prefix-R_t
    matrix (argmin stays with the caller — it is O(U) in jnp).

    ``tiles=(bb, bu)`` overrides the tiling; the interpret-mode default is
    a full-extent U tile for bitwise parity with the jnp sweep."""
    B, U = caps_sorted.shape
    assert k_sorted.shape == (B, U) and coefs.shape == (B, N_COEF)
    if tiles:
        bb, bu = tiles
    else:
        bb, bu = min(BB, B), (U if interpret else min(BU, U))
    pad_b, pad_u = (-B) % bb, (-U) % bu
    if pad_b or pad_u:
        caps_sorted = jnp.pad(caps_sorted, ((0, pad_b), (0, pad_u)),
                              constant_values=1.0)
        k_sorted = jnp.pad(k_sorted, ((0, pad_b), (0, pad_u)),
                           constant_values=1.0)
        coefs = jnp.pad(coefs, ((0, pad_b), (0, 0)), constant_values=1.0)
    bp, up = B + pad_b, U + pad_u
    grid = (bp // bb, up // bu)
    out = pl.pallas_call(
        functools.partial(_prefix_kernel, bu=bu),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bu), lambda i, j: (i, j)),
                  pl.BlockSpec((bb, bu), lambda i, j: (i, j)),
                  pl.BlockSpec((bb, N_COEF), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bb, bu), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, up), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, 1), jnp.float32)],
        interpret=interpret,
    )(caps_sorted, k_sorted, coefs)
    return out[:B, :U]
