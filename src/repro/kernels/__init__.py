"""Pallas TPU kernels for the OBCSAA compression pipeline.

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the jit'd
wrappers (interpret=True on CPU)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
