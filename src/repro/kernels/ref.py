"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

``sign_pm1`` is re-exported from the canonical definition in
``kernels/sign.py`` (sign(0) = +1; one shared helper repo-wide,
DESIGN.md §13)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sign import pack_bool, pack_signs, sign_pm1, unpack_bits

__all__ = ["sign_pm1", "cs_project_sign_ref", "cs_project_pack_ref",
           "sign_residual_planes_ref", "topk_select_ref", "backproject_ref",
           "biht_ref"]


def cs_project_sign_ref(phi: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
    """phi: (S, D); chunks: (n, D) -> ±1 signs (n, S)."""
    return sign_pm1(jnp.einsum("sd,nd->ns", phi, chunks))


def cs_project_pack_ref(phi: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
    """Packed-codec oracle: phi (S, D); chunks (n, D) -> uint32 (n, S//32).

    ``pack_signs`` applies the shared ``x >= 0`` predicate directly to the
    projection, so this equals ``pack_signs(cs_project_sign_ref(...))``
    bit for bit (DESIGN.md §13)."""
    return pack_signs(jnp.einsum("sd,nd->ns", phi, chunks))


def sign_residual_planes_ref(phi: jnp.ndarray, x: jnp.ndarray,
                             y_packed: jnp.ndarray):
    """Packed BIHT residual oracle -> (plus, minus) uint32 (n, S//32).

    With ±1 measurements y, the sign-consistency residual y − sign(Φx)
    takes values in {−2, 0, +2}; the two bit-planes record the +2 lanes
    (y=+1, sign=−1) and −2 lanes (y=−1, sign=+1): resid = 2·(plus − minus)
    (DESIGN.md §13)."""
    yb = unpack_bits(y_packed, jnp.bool_)
    sb = jnp.einsum("sd,nd->ns", phi, x) >= 0
    return pack_bool(yb & ~sb), pack_bool(sb & ~yb)


def topk_select_ref(chunks: jnp.ndarray, k: int):
    """Exact per-row top-k by magnitude. Returns (masked values, mask)."""
    a = jnp.abs(chunks)
    kth = jax.lax.top_k(a, k)[0][..., -1:]
    mask = a >= kth
    over = jnp.cumsum(mask, axis=-1) <= k
    mask = mask & over
    return chunks * mask, mask


def backproject_ref(x: jnp.ndarray, resid: jnp.ndarray, phi: jnp.ndarray,
                    tau: float) -> jnp.ndarray:
    """x + tau * resid @ phi. x: (n, D); resid: (n, S); phi: (S, D)."""
    return x + tau * jnp.einsum("ns,sd->nd", resid, phi)


def biht_ref(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int,
             tau: float) -> jnp.ndarray:
    """Full BIHT loop (sign-consistency), unit-normalized per row."""
    S = phi.shape[0]

    def step(x, _):
        resid = y - sign_pm1(jnp.einsum("sd,nd->ns", phi, x))
        x = backproject_ref(x, resid, phi, tau / S)
        x, _ = topk_select_ref(x, k)
        return x, None

    x0, _ = topk_select_ref(jnp.einsum("sd,ns->nd", phi, y) / S, k)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)
