"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sign_pm1(x):
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def cs_project_sign_ref(phi: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
    """phi: (S, D); chunks: (n, D) -> ±1 signs (n, S)."""
    return sign_pm1(jnp.einsum("sd,nd->ns", phi, chunks))


def topk_select_ref(chunks: jnp.ndarray, k: int):
    """Exact per-row top-k by magnitude. Returns (masked values, mask)."""
    a = jnp.abs(chunks)
    kth = jax.lax.top_k(a, k)[0][..., -1:]
    mask = a >= kth
    over = jnp.cumsum(mask, axis=-1) <= k
    mask = mask & over
    return chunks * mask, mask


def backproject_ref(x: jnp.ndarray, resid: jnp.ndarray, phi: jnp.ndarray,
                    tau: float) -> jnp.ndarray:
    """x + tau * resid @ phi. x: (n, D); resid: (n, S); phi: (S, D)."""
    return x + tau * jnp.einsum("ns,sd->nd", resid, phi)


def biht_ref(y: jnp.ndarray, phi: jnp.ndarray, k: int, iters: int,
             tau: float) -> jnp.ndarray:
    """Full BIHT loop (sign-consistency), unit-normalized per row."""
    S = phi.shape[0]

    def step(x, _):
        resid = y - sign_pm1(jnp.einsum("sd,nd->ns", phi, x))
        x = backproject_ref(x, resid, phi, tau / S)
        x, _ = topk_select_ref(x, k)
        return x, None

    x0, _ = topk_select_ref(jnp.einsum("sd,ns->nd", phi, y) / S, k)
    x, _ = jax.lax.scan(step, x0, None, length=iters)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, 1e-12)
