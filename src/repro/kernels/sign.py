"""Canonical 1-bit sign convention + the 32-per-uint32 packed codec.

This module is THE definition of sign(0) for the whole repo (DESIGN.md §13):
``sign_pm1`` maps 0 to +1 (``x >= 0``), as required for the
gradient-independent power constraint (paper eq. 11) — every transmitted
symbol must be ±1, never 0. The Pallas epilogues (kernels/cs_project.py),
the jnp oracles (kernels/ref.py) and the quantizer (core/quantize.py) all
import it from here; with packed words a convention mismatch would corrupt
a whole 32-lane word, not one symbol, so there is exactly one definition.

Packed codec contract (DESIGN.md §13):
- 32 signs per uint32 word along the LAST axis; the last axis length must
  be a multiple of ``PACK`` (= 32).
- Word ``j`` covers lanes ``[32j, 32j+32)``; bit ``b`` (LSB-first) is lane
  ``32j + b``.
- bit = 1  ⇔  sign = +1  ⇔  the pre-sign value was >= 0.

``pack_signs`` applies ``x >= 0`` directly, so it both packs ±1 symbol
arrays exactly AND acts as a fused sign+pack on raw projections (eq. 7) —
the two uses agree bit for bit because ``sign_pm1`` uses the same
predicate. ``unpack_signs`` reproduces the exact ±1.0 floats ``sign_pm1``
would have produced, which is what makes the packed kernel paths
bit-for-bit equal to the f32 sign paths: identical values into identical
``dot_general``/einsum contractions.

Everything here is plain jnp so it is usable both outside kernels and
inside Pallas kernel bodies (interpret mode on CPU; on TPU the
reshape/shift formulation lowers through Mosaic with lane padding for the
narrow packed axis).
"""
from __future__ import annotations

import jax.numpy as jnp

PACK = 32  # signs per uint32 word


def sign_pm1(x: jnp.ndarray) -> jnp.ndarray:
    """Strict ±1 sign, sign(0) := +1 (paper eq. 7/11). Never returns 0."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _shifts() -> jnp.ndarray:
    return jnp.arange(PACK, dtype=jnp.uint32)


def packed_width(n_lanes: int) -> int:
    """Words needed for ``n_lanes`` signs (must divide exactly)."""
    if n_lanes % PACK:
        raise ValueError(
            f"packed codec needs the sign axis to be a multiple of "
            f"{PACK}; got {n_lanes} (DESIGN.md §13)")
    return n_lanes // PACK


def pack_signs(x: jnp.ndarray) -> jnp.ndarray:
    """(..., S) real -> (..., S//32) uint32; bit = 1 ⇔ x >= 0 (sign +1).

    Exact on ±1 symbol arrays and equally valid on raw projections (the
    fused sign+pack of eq. 7): both reduce to the ``x >= 0`` predicate."""
    w = packed_width(x.shape[-1])
    bits = (x >= 0).reshape(x.shape[:-1] + (w, PACK)).astype(jnp.uint32)
    return jnp.sum(bits << _shifts(), axis=-1, dtype=jnp.uint32)


def pack_bool(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., S) bool -> (..., S//32) uint32 (kernel-epilogue helper)."""
    w = packed_width(bits.shape[-1])
    b = bits.reshape(bits.shape[:-1] + (w, PACK)).astype(jnp.uint32)
    return jnp.sum(b << _shifts(), axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jnp.ndarray, dtype=jnp.int32) -> jnp.ndarray:
    """(..., W) uint32 -> (..., W*32) {0, 1} in ``dtype``."""
    bits = (packed[..., None] >> _shifts()) & jnp.uint32(1)
    return bits.reshape(packed.shape[:-1] + (-1,)).astype(dtype)


def unpack_signs(packed: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """(..., W) uint32 -> (..., W*32) exact ±1 in ``dtype``.

    Bit-for-bit inverse of ``pack_signs`` on ±1 data: reproduces the same
    float values ``sign_pm1`` produces, so downstream contractions match
    the f32 sign path exactly."""
    bits = unpack_bits(packed, jnp.float32)
    return (2.0 * bits - 1.0).astype(dtype)
