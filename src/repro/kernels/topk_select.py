"""Per-chunk top-κ selection kernel (the sparse_κ operator, eq. 6).

A sort-free magnitude-threshold search: 32 rounds of bisection on the
per-row threshold t such that #{|x| ≥ t} = κ, entirely in VMEM (vector unit
work, no MXU). Exact for rows with distinct magnitudes — bisection resolves
the gap between the κ-th and (κ+1)-th magnitude; ties may admit >κ entries
(measure-zero for float gradients; the jnp oracle breaks ties by index).

Each program owns a (BN, D) row-block; D up to 8192 keeps the block < 4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 64
N_BISECT = 32


def _topk_kernel(x_ref, val_ref, mask_ref, *, k):
    x = x_ref[...]
    a = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(a, axis=-1, keepdims=True)            # (bn, 1)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        # too many selected -> raise threshold; too few -> lower it
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    # lo is the largest tested threshold with count > k; select with hi
    mask = a >= jnp.minimum(hi, jnp.max(a, axis=-1, keepdims=True))
    # guarantee at least k selected: fall back to lo when hi overshoots
    cnt_hi = jnp.sum(mask.astype(jnp.int32), axis=-1, keepdims=True)
    mask = jnp.where(cnt_hi >= k, mask, a >= lo)
    val_ref[...] = (x * mask).astype(val_ref.dtype)
    mask_ref[...] = mask.astype(mask_ref.dtype)


def topk_select(chunks: jnp.ndarray, k: int, *, interpret: bool = False,
                bn: int = None):
    """chunks: (n, D). Returns (masked values, int8 mask).

    ``bn`` overrides the rows-per-program tile (the fused decode loop keeps
    all rows in one program in interpret mode)."""
    n, d = chunks.shape
    bn = min(BN, n) if bn is None else bn
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    val, mask = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                   pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), chunks.dtype),
                   jax.ShapeDtypeStruct((n, d), jnp.int8)],
        interpret=interpret,
    )(chunks)
    return val, mask
