"""Engine carry and arms pytrees (DESIGN.md §11).

``EngineState`` is the ``lax.scan`` carry for one experiment arm — model
parameters, optimizer state, the complex Gauss-Markov fade state, the
previous schedule (warm-start reset mask), the decoder warm-start chunks
and the error-feedback residuals. Leaves that a static ``FLConfig`` turns
off are ``None`` (an empty pytree node), so the carry structure is fixed
per configuration and the same state threads through ``jit``/``scan``/
``vmap`` unchanged.

``Arms`` holds the DYNAMIC per-arm sweep axes — PRNG key, noise variance
σ², power budget P^Max, learning rate α — the quantities an experiment
grid varies without retracing. Static axes (κ, S, aggregator, scheduler)
live in ``FLConfig``; a grid over those is a loop over engine builds,
each of which still vmaps its dynamic arms in one compiled program.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class EngineState(NamedTuple):
    """Per-arm scan carry (donated across chunk calls)."""
    params: Any                        # model pytree
    opt_state: Any                     # optimizer state pytree
    fade: jnp.ndarray                  # (U,) complex64 Gauss-Markov state
    prev_beta: jnp.ndarray             # (U,) f32; -1 before round 0
    decode_x0: Optional[jnp.ndarray]   # (n_chunks, D_c) warm start | None
    residual: Optional[jnp.ndarray]    # (U, D) EF residuals | None
    # ADMM multipliers of the last solved schedule ((U,)-leaf AdmmDuals),
    # carried next to prev_beta to warm-start the next round's P2 under
    # fade coherence (FLConfig.sched_warm_duals; DESIGN.md §15) | None
    sched_duals: Any = None


class RoundStats(NamedTuple):
    """Per-round scheduling + theory stats, emitted EVERY round as scan
    outputs — the dense trajectory the eval-gated ``RoundLog`` used to
    drop. ``budget`` is the predicted Theorem-1 ``ErrorBudget`` pytree
    (repro.theory, DESIGN.md §12) evaluated at this round's (β, b_t, σ²)
    — ``None`` unless the aggregator is the 1-bit CS pipeline eq. 19
    models (``obcsaa``); ``agg_err`` is the measured ‖ĝ−ḡ‖² probe —
    ``None`` unless ``FLConfig.probe_agg_error`` is on. ``None`` is an
    empty pytree node, so the scan output structure stays fixed per
    build."""
    n_scheduled: jnp.ndarray           # i32: Σβ_t
    b_t: jnp.ndarray                   # f32: power scaling factor
    budget: Any = None                 # ErrorBudget | None (theory track)
    agg_err: Optional[jnp.ndarray] = None   # f32: ‖ĝ−ḡ‖² probe | None


class SweepCheckpoint(NamedTuple):
    """Everything ``run_sweep`` needs to continue bit-for-bit after a
    restart (DESIGN.md §14): the (A, ...)-stacked ``EngineState`` carry —
    params, optimizer state, complex fade state, previous β, decoder
    warm-start chunks, EF residuals — the ``Arms`` it was advanced under
    (restore verifies these bitwise; resuming under different arms would
    silently invalidate every trajectory), and ``t_next``, the first round
    not yet run. Because round t keys are ``fold_in(arm.key, t)`` on the
    ABSOLUTE round index (engine/core.py), a restored carry replays the
    identical channel/noise draws with no RNG state to serialize."""
    state: Any                         # EngineState, (A, ...)-stacked
    arms: Any                          # Arms the carry was advanced under
    t_next: jnp.ndarray                # i32 scalar: first round not run


class Arms(NamedTuple):
    """Dynamic experiment-arm axes; leaves are scalars for a single arm or
    (A, ...)-stacked for a vmapped sweep."""
    key: jnp.ndarray                   # per-arm base PRNG key
    noise_var: jnp.ndarray             # σ² (mW)
    p_max: jnp.ndarray                 # P^Max (mW)
    lr: jnp.ndarray                    # learning rate α


def single_arm(cfg) -> Arms:
    """The one-arm ``Arms`` implied by an ``FLConfig`` (seed + obcsaa
    noise/power + learning rate)."""
    return Arms(key=jax.random.PRNGKey(cfg.seed),
                noise_var=jnp.float32(cfg.obcsaa.noise_var),
                p_max=jnp.float32(cfg.obcsaa.p_max),
                lr=jnp.float32(cfg.learning_rate))


def make_arms(cfg, *, seeds=None, noise_var=None, p_max=None,
              lr=None) -> Arms:
    """Broadcast sweep axes to a common arm count A.

    Every argument accepts a scalar or a sequence; unset axes default to
    the ``FLConfig`` values. At least one axis must be a sequence (that
    fixes A). Seeds map to per-arm PRNG keys."""
    axes = {"seeds": seeds, "noise_var": noise_var, "p_max": p_max,
            "lr": lr}
    lengths = [len(v) for v in axes.values()
               if v is not None and np.ndim(v) > 0]
    if not lengths:
        raise ValueError("make_arms needs at least one sequence axis "
                         "(seeds / noise_var / p_max / lr)")
    A = max(lengths)
    for name, v in axes.items():
        if v is not None and np.ndim(v) > 0 and len(v) not in (1, A):
            raise ValueError(f"arms axis {name!r} has length {len(v)}, "
                             f"expected 1 or {A}")

    def bcast(v, default):
        v = default if v is None else v
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32).reshape(-1),
                                (A,))

    s = seeds if seeds is not None else cfg.seed
    s = jnp.broadcast_to(jnp.asarray(s, jnp.uint32), (A,))
    keys = jax.vmap(jax.random.PRNGKey)(s)
    return Arms(key=keys,
                noise_var=bcast(noise_var, cfg.obcsaa.noise_var),
                p_max=bcast(p_max, cfg.obcsaa.p_max),
                lr=bcast(lr, cfg.learning_rate))


def n_arms(arms: Arms) -> int:
    return int(arms.noise_var.shape[0]) if arms.noise_var.ndim else 1
