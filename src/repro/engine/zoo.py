"""repro.engine.zoo — shard_map'd full FL rounds at model-zoo scale
(DESIGN.md §14).

The scan engine (engine/core.py) materialises per-worker gradients as a
dense (U, D) array — fine for the paper's §V simulations, hopeless at
≥1B parameters. This module runs the SAME round pipeline (eq. 3 local
gradients → eq. 6-7 compress → eq. 10 power scaling → eq. 12-13 MAC+AWGN
→ eq. 43 decode → eq. 14 update) as one ``jax.shard_map`` program over
the whole device mesh, with nothing dense at full D ever replicated:

* Parameters live chunked as a (n_chunks, D_c) f32 array whose chunk axis
  is partitioned over ``("model",) + worker_axes`` — model-major, so the
  device at (worker d, model m) owns the contiguous chunk block
  ``m·n_half + d·n_local`` (n_half = n_chunks / n_model,
  n_local = n_half / n_workers). Spec from ``dist.best_spec`` via
  :func:`param_spec`.
* Each FL worker (= its column of ``n_model`` devices) gathers one
  MODEL-HALF of the parameters over the worker axes
  (``all_gather(tiled)``), generates its local gradients for that half,
  and compresses them in ``lax.map`` blocks of ``block_chunks`` chunks —
  peak memory is one model-half plus one block, never (U, D).
* The uplink is the packed 1-bit wire when ``ob.packed``: uint32 sign
  words into the exact int32 bit-count MAC (``collectives.psum_bits_mac``
  via ``obcsaa.shardmap_mac``), worker-axis psum = the over-the-air
  superposition (DESIGN.md §3/§13).
* The PS side redraws the FULL (n_chunks, S_c) AWGN from one shared key
  on every device and each device decodes only its own quarter
  (``collectives.shard_slice``), updating its local parameter block in
  place — the decoded estimate is never gathered.

Gradients come from either real per-worker grads handed in as a
(U, n_chunks, D_c) array sharded (workers × model) — the zoo smoke tier
path, U must equal the mesh worker count — or from a deterministic
surrogate objective ½‖p − c_u‖² whose per-worker anchors c_u hash the
GLOBAL element index (mesh-layout invariant), so the ≥1B benchmark needs
no dataset and any mesh produces bit-identical rounds.

:func:`ZooRound.reference_round` is the single-device oracle: same
schedule, same surrogate, same int32 superposition, same full-noise-draw
— the parity target for tests/test_zoo.py. Scheduling (P2, eq. 24) and
the Theorem-1 ``ErrorBudget`` (eq. 19 via ``budget_geometry``) run
outside the shard_map, exactly as in the scan engine.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import channel as chan
from repro.core.obcsaa import (OBCSAAConfig, compress_chunks,
                               reconstruct_chunks, shardmap_mac)
from repro.core.sparsify import flatten_pytree
from repro.dist import collectives as coll
from repro.engine.core import budget_geometry
from repro.launch.mesh import num_workers, worker_axes
from repro.sched.admm import admm_solve_batched_jit
from repro.sched.greedy import greedy_solve_batched
from repro.sched.problem import BatchedProblem
from repro.theory.bounds import AnalysisConstants, ErrorBudget, error_budget


class ZooStats(NamedTuple):
    """Per-round diagnostics of one zoo round (host-visible scalars)."""
    n_scheduled: jnp.ndarray            # |M_t| (i32)
    b_t: jnp.ndarray                    # eq. 10 power scale (f32)
    ghat_norm: jnp.ndarray              # ‖ĝ_t‖ over the FULL vector (f32)
    budget: Optional[ErrorBudget]       # Theorem-1 eq. 19 terms (§12)


def _hash_u01(idx, widx, t):
    """U(0,1) from (global element index, worker, round) — a splitmix-style
    integer hash, so the surrogate anchors depend only on GLOBAL indices
    and are identical whatever mesh (or single device) computes them."""
    x = idx * jnp.uint32(0x9E3779B1)
    x = x ^ ((widx.astype(jnp.uint32) + 1) * jnp.uint32(0x85EBCA77))
    x = x ^ ((t.astype(jnp.uint32) + 1) * jnp.uint32(0xC2B2AE3D))
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x85EBCA77)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE3D)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def param_spec(mesh) -> P:
    """PartitionSpec of the chunked (n_chunks, D_c) parameter array: chunk
    axis over model-major ``("model",) + worker_axes`` (DESIGN.md §14)."""
    parts = (("model",) if "model" in mesh.axis_names else ()) \
        + worker_axes(mesh)
    return P(parts if len(parts) > 1 else parts[0], None)


def grads_spec(mesh) -> P:
    """PartitionSpec of a (U, n_chunks, D_c) per-worker gradient array:
    workers over the worker axes, chunks over the model axis."""
    waxes = worker_axes(mesh)
    w = waxes if len(waxes) > 1 else waxes[0]
    m = "model" if "model" in mesh.axis_names else None
    return P(w, m, None)


class ZooRound:
    """One built zoo-round program for (ob, D, mesh). See module docstring.

    ``round_gen(params, t, key, noise_var, p_max, lr)`` and
    ``round_from_grads(params, grads, t, ...)`` are jitted; ``params`` is
    the sharded (n_chunks, D_c) array from :meth:`shard_params` and comes
    back with the same sharding, so rounds chain without reshards."""

    def __init__(self, ob: OBCSAAConfig, D: int, mesh, *,
                 scheduler: str = "all",
                 const: Optional[AnalysisConstants] = None,
                 sched_cfg=None, grad_scale: float = 0.05,
                 block_chunks: int = 64, n_chunks: Optional[int] = None):
        if D >= 2 ** 32:
            raise ValueError(
                f"ZooRound(D={D}): the zoo surrogate hashes uint32 element "
                "indices, so D must stay below 2**32 (a 64-bit index path "
                "is the escape hatch)")
        self.ob, self.D, self.mesh = ob, int(D), mesh
        self.waxes = worker_axes(mesh)
        self.U = num_workers(mesh)
        self.n_model = int(mesh.shape.get("model", 1))
        self.grad_scale = jnp.float32(grad_scale)
        self.scheduler = scheduler
        self.const = const or AnalysisConstants()
        self.sched_cfg = sched_cfg
        # chunk count padded so every device owns an equal block; callers
        # with their own flat layout (zoo-train) pass n_chunks explicitly
        gran = self.n_model * self.U
        if n_chunks is None:
            n_raw = -(-self.D // ob.chunk)
            n_chunks = -(-n_raw // gran) * gran
        elif n_chunks % gran or n_chunks * ob.chunk < self.D:
            raise ValueError(
                f"ZooRound(n_chunks={n_chunks}): with OBCSAAConfig.chunk="
                f"{ob.chunk} the chunk count must cover D={self.D} and "
                f"divide evenly over the mesh granularity {gran} "
                f"(= model {self.n_model} x workers {self.U}); every "
                "device owns a whole chunk block (DESIGN.md §14)")
        self.n_chunks = n_chunks
        self.D_pad = self.n_chunks * ob.chunk
        self.n_half = self.n_chunks // self.n_model
        self.n_local = self.n_half // self.U
        self.block = next(b for b in range(min(block_chunks, self.n_half),
                                           0, -1) if self.n_half % b == 0)
        self.block_dec = next(b for b in range(min(block_chunks,
                                                   self.n_local),
                                               0, -1) if self.n_local % b == 0)
        self.spec = param_spec(mesh)
        self.grads_spec = grads_spec(mesh)
        _, s_eff, kappa_eff = budget_geometry(ob, self.D_pad)
        self._s_eff, self._kappa_eff = s_eff, kappa_eff
        self._kw = jnp.ones((self.U,), jnp.float32)
        self._build()

    # -- host-side layout helpers ------------------------------------------

    def chunk_params(self, params) -> jnp.ndarray:
        """Flat (D,) array or pytree -> padded f32 (n_chunks, D_c)."""
        flat = params if isinstance(params, jnp.ndarray) and params.ndim == 1 \
            else flatten_pytree(params)[0]
        flat = flat.astype(jnp.float32)
        return jnp.pad(flat, (0, self.D_pad - self.D)).reshape(
            self.n_chunks, self.ob.chunk)

    def shard_params(self, chunked) -> jnp.ndarray:
        return jax.device_put(chunked, NamedSharding(self.mesh, self.spec))

    def chunk_worker_grads(self, grads) -> jnp.ndarray:
        """(U, D) per-worker grads -> sharded (U, n_chunks, D_c). U must
        equal the mesh worker count — FL workers ARE the worker-axis
        shards (DESIGN.md §3)."""
        g = jnp.asarray(grads, jnp.float32)
        assert g.shape == (self.U, self.D), (g.shape, self.U, self.D)
        g = jnp.pad(g, ((0, 0), (0, self.D_pad - self.D)))
        g = g.reshape(self.U, self.n_chunks, self.ob.chunk)
        return jax.device_put(g, NamedSharding(self.mesh, self.grads_spec))

    def unchunk(self, chunked) -> jnp.ndarray:
        """(n_chunks, D_c) -> flat (D,) on the host (drops the padding —
        pad-chunk parameters are never read back)."""
        return jnp.asarray(chunked).reshape(-1)[:self.D]

    # -- round pieces ------------------------------------------------------

    def _schedule(self, h, noise_var, p_max):
        """P2 at this round's channels (eq. 24), host-of-shard_map side —
        mirrors engine/core.py so zoo and scan rounds schedule alike."""
        ob = self.ob
        bp = BatchedProblem.from_arrays(
            h[None], self._kw[None], p_max, noise_var, D=self.D,
            S=ob.measure, kappa=ob.topk, const=self.const)
        if self.scheduler == "all":
            beta = jnp.ones_like(bp.h)
            b_t = bp.optimal_bt(beta)
        elif self.scheduler == "greedy_batched":
            beta, b_t, _ = greedy_solve_batched(bp, self.sched_cfg)
        elif self.scheduler in ("admm_batched", "admm_batched_jit"):
            beta, b_t, _ = admm_solve_batched_jit(bp, self.sched_cfg)
        else:
            raise ValueError(f"zoo scheduler {self.scheduler!r} must be "
                             "jittable: all | greedy_batched | admm_batched")
        return beta[0], b_t[0]

    def _surrogate_grads(self, p_blk, chunk_off, widx, t):
        """Worker ``widx``'s gradient of ½‖p − c_u‖² on a chunk block:
        g = p − c_u, anchors c_u = grad_scale·(U(0,1) − ½) hashed from the
        GLOBAL element index. Padding elements (index ≥ D) get zero
        gradients, so pad chunks carry zero magnitude and decode to zero
        under magnitude tracking."""
        nb, dc = p_blk.shape
        idx = ((chunk_off.astype(jnp.uint32)
                + jnp.arange(nb, dtype=jnp.uint32))[:, None]
               * jnp.uint32(dc) + jnp.arange(dc, dtype=jnp.uint32)[None, :])
        c = self.grad_scale * (_hash_u01(idx, widx, t) - 0.5)
        return jnp.where(idx < jnp.uint32(self.D), p_blk - c, 0.0)

    def _mac_decode(self, signs, mags, beta, b_t, noise_key, noise_var,
                    widx, half0, phi):
        """MAC + decode of both round bodies, INSIDE shard_map: packed MAC
        over the worker axes (eq. 12), post-processing + AWGN (eq. 13),
        decode of this device's quarter only (eq. 43). Returns
        (ghat (n_local, D_c), ‖ĝ‖² over the full vector) — the update is
        applied by the caller, so stateful optimizers (engine/zoo_train.py,
        DESIGN.md §17) reuse the identical decode path."""
        ob = self.ob
        y, ksum, mag_sum = shardmap_mac(
            ob, signs, mags, self.waxes, k_weight=jnp.float32(1.0),
            beta_i=beta[widx], b_t=b_t)
        denom = jnp.maximum(ksum * b_t, 1e-12)
        # one shared draw of the FULL noise field, sliced per device: the
        # single-device reference slices the same field, so AWGN is
        # bit-identical whatever the mesh shape (mesh-elastic parity)
        noise = chan.draw_noise(noise_key, (self.n_chunks, ob.measure),
                                noise_var)
        q0 = half0 + widx * self.n_local
        yq = coll.shard_slice(y, self.waxes)            # (n_local, S_c)
        yq = (yq + jax.lax.dynamic_slice_in_dim(noise, q0, self.n_local, 0)
              ) / denom
        mbar_q = None
        if ob.magnitude_tracking:
            mbar_q = coll.shard_slice(mag_sum, self.waxes) \
                / jnp.maximum(ksum, 1e-12)
        ghat = self._decode_blocks(yq, mbar_q, phi)
        axes_all = self.waxes + (("model",) if "model"
                                 in self.mesh.axis_names else ())
        gn2 = coll.psum(jnp.sum(ghat * ghat), axes_all)
        return ghat, gn2

    def _mac_decode_update(self, pl, signs, mags, beta, b_t, noise_key,
                           noise_var, lr, widx, half0, phi):
        """_mac_decode + the plain eq. 14 local update (the surrogate and
        array-fed round bodies; zoo_train applies its optimizer instead)."""
        ghat, gn2 = self._mac_decode(signs, mags, beta, b_t, noise_key,
                                     noise_var, widx, half0, phi)
        return pl - lr * ghat, gn2

    def _decode_blocks(self, yq, mbar_q, phi):
        """``reconstruct_chunks`` behind a ``lax.map`` block boundary of
        ``block_dec`` rows — the SAME loop-body shape in the sharded round
        and in the single-device reference.

        XLA compiles the IHT decode differently for different leading row
        counts in some contexts (observed inside shard_map at n_local 25
        and 32 on CPU), which drifts final ulps between the mesh's
        (n_local, S_c) decode and the oracle's (n_chunks, S_c) decode. A
        loop body of identical shape on both sides pins one compiled
        decode program, keeping the round bitwise mesh-invariant at every
        chunk geometry — and bounds decode workspace to ``block_dec``
        chunks, which is what lets the ≥1B rounds keep activation-sized
        decode buffers off the device (DESIGN.md §14)."""
        ob, b = self.ob, self.block_dec
        nb = yq.shape[0] // b
        if mbar_q is None:
            out = jax.lax.map(
                lambda yb: reconstruct_chunks(ob, yb, None, phi)
                .reshape(b, ob.chunk),
                yq.reshape((nb, b) + yq.shape[1:]))
        else:
            out = jax.lax.map(
                lambda args: reconstruct_chunks(ob, args[0], args[1], phi)
                .reshape(b, ob.chunk),
                (yq.reshape((nb, b) + yq.shape[1:]),
                 mbar_q.reshape(nb, b)))
        return out.reshape(nb * b, ob.chunk)

    def _build(self):
        ob, waxes = self.ob, self.waxes
        n_half, block = self.n_half, self.block
        phi = None  # rebuilt per trace from ob.phi() inside compress/decode

        def model_idx():
            return (coll.axis_index(("model",))
                    if "model" in self.mesh.axis_names
                    else jnp.zeros((), jnp.int32))

        def body_gen(pl, beta, b_t, noise_key, noise_var, lr, t):
            widx = coll.axis_index(waxes)
            half0 = model_idx() * n_half
            ph = coll.all_gather(pl, waxes, tiled=True)  # (n_half, D_c)
            nb = n_half // block
            offs = half0 + jnp.arange(nb, dtype=jnp.int32) * block

            def one(args):
                p_blk, off = args
                g = self._surrogate_grads(p_blk, off, widx, t)
                return compress_chunks(ob, g, phi)

            signs, mags = jax.lax.map(
                one, (ph.reshape(nb, block, ob.chunk), offs))
            signs = signs.reshape((n_half,) + signs.shape[2:])
            return self._mac_decode_update(
                pl, signs, mags.reshape(n_half), beta, b_t, noise_key,
                noise_var, lr, widx, half0, phi)

        def body_grads(pl, gl, beta, b_t, noise_key, noise_var, lr):
            widx = coll.axis_index(waxes)
            half0 = model_idx() * n_half
            signs, mags = compress_chunks(ob, gl[0], phi)  # (n_half, D_c)
            return self._mac_decode_update(
                pl, signs, mags, beta, b_t, noise_key, noise_var, lr,
                widx, half0, phi)

        rep = P(None)
        sc = P()
        sm_gen = jax.shard_map(
            body_gen, mesh=self.mesh,
            in_specs=(self.spec, rep, sc, rep, sc, sc, sc),
            out_specs=(self.spec, sc), check_vma=False)
        sm_grads = jax.shard_map(
            body_grads, mesh=self.mesh,
            in_specs=(self.spec, self.grads_spec, rep, sc, rep, sc, sc),
            out_specs=(self.spec, sc), check_vma=False)

        def prologue(t, key, noise_var, p_max):
            """Per-round schedule + keys, shared with reference_round:
            absolute-round PRNG folds (fold 0 → fades, fold 1 → AWGN),
            i.i.d. block fading (§V)."""
            t = jnp.asarray(t, jnp.int32)
            k_t = jax.random.fold_in(key, t)
            h, _ = chan.draw_fades(jax.random.fold_in(k_t, 0), (self.U,))
            beta, b_t = self._schedule(h, noise_var, p_max)
            return t, beta, b_t, jax.random.fold_in(k_t, 1)

        def stats(beta, b_t, gn2, noise_var):
            budget = error_budget(self.const, D=self.D_pad, S=self._s_eff,
                                  kappa=self._kappa_eff, beta=beta,
                                  k_weights=self._kw, b_t=b_t,
                                  noise_var=noise_var)
            return ZooStats(n_scheduled=jnp.sum(beta > 0).astype(jnp.int32),
                            b_t=b_t, ghat_norm=jnp.sqrt(gn2), budget=budget)

        def round_gen(params, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = prologue(t, key, noise_var, p_max)
            pl2, gn2 = sm_gen(params, beta, b_t, nkey,
                              jnp.float32(noise_var), jnp.float32(lr), t)
            return pl2, stats(beta, b_t, gn2, noise_var)

        def round_from_grads(params, grads, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = prologue(t, key, noise_var, p_max)
            pl2, gn2 = sm_grads(params, grads, beta, b_t, nkey,
                                jnp.float32(noise_var), jnp.float32(lr))
            return pl2, stats(beta, b_t, gn2, noise_var)

        donate = (0,) if jax.default_backend() != "cpu" else ()
        self.round_gen = jax.jit(round_gen, donate_argnums=donate)
        self.round_from_grads = jax.jit(round_from_grads,
                                        donate_argnums=donate)
        self._prologue = prologue
        self._stats = stats
        # the reference MUST be jitted too: the op sequence is identical,
        # but eager-mode execution fuses f32 arithmetic differently from
        # the compiled sharded round and drifts final ulps
        self._ref_gen = jax.jit(
            lambda c, t, key, nv, pm, lr:
            self._reference_impl(c, t, key, nv, pm, lr, None))
        self._ref_grads = jax.jit(
            lambda c, g, t, key, nv, pm, lr:
            self._reference_impl(c, t, key, nv, pm, lr, g))

    # -- single-device oracle ----------------------------------------------

    def reference_round(self, chunked, t, key, noise_var, p_max, lr,
                        grads=None):
        """The same round on ONE device, no collectives: the parity
        target, bit-for-bit equal to the sharded round on the packed wire
        (exact int32 superposition on both sides; the f32 symbol path
        reduces in psum order on the mesh and may differ in final ulps).

        ``chunked``: replicated (n_chunks, D_c). ``grads``: optional
        (U, n_chunks, D_c)."""
        if grads is not None:
            return self._ref_grads(chunked, grads, t, key, noise_var,
                                   p_max, lr)
        return self._ref_gen(chunked, t, key, noise_var, p_max, lr)

    def _reference_impl(self, chunked, t, key, noise_var, p_max, lr,
                        grads):
        ob, U = self.ob, self.U
        t, beta, b_t, nkey = self._prologue(t, key, noise_var, p_max)

        def one(u):
            g = grads[u] if grads is not None else self._surrogate_grads(
                chunked, jnp.zeros((), jnp.int32), u, t)
            return compress_chunks(ob, g, None)

        signs, mags = jax.lax.map(one, jnp.arange(U, dtype=jnp.int32))
        return self._reference_tail(chunked, signs, mags, beta, b_t, nkey,
                                    noise_var, lr)

    def _reference_mac_decode(self, signs, mags, beta, b_t, nkey,
                              noise_var):
        """Single-device MAC + decode given per-worker (U, n_chunks, ...)
        compressed uploads — shared by the surrogate, array-fed, and
        zoo-train (engine/zoo_train.py) oracles. Returns (ghat, ‖ĝ‖²);
        the update is applied by the caller so stateful optimizers reuse
        the identical decode path (DESIGN.md §17)."""
        ob = self.ob
        if ob.packed:
            from repro.kernels.sign import unpack_bits
            contrib = (2 * unpack_bits(signs, jnp.int32) - 1) \
                * beta.astype(jnp.int32)[:, None, None]
            y = jnp.sum(contrib, axis=0).astype(jnp.float32) * b_t
        else:
            w = (beta * b_t).astype(signs.dtype)
            y = jnp.einsum("u,ucs->cs", w, signs)
        ksum = jnp.sum(beta)
        denom = jnp.maximum(ksum * b_t, 1e-12)
        noise = chan.draw_noise(nkey, (self.n_chunks, ob.measure), noise_var)
        y = (y + noise) / denom
        mbar = None
        if ob.magnitude_tracking:
            mbar = jnp.einsum("u,uc->c", beta.astype(mags.dtype), mags) \
                / jnp.maximum(ksum, 1e-12)
        # same block_dec loop-body shape as the mesh decode (bitwise
        # parity at every geometry; see _decode_blocks)
        ghat = self._decode_blocks(y, mbar, None)
        gn2 = jnp.sum(ghat * ghat)
        return ghat, gn2

    def _reference_tail(self, chunked, signs, mags, beta, b_t, nkey,
                        noise_var, lr):
        """_reference_mac_decode + the plain eq. 14 update."""
        ghat, gn2 = self._reference_mac_decode(signs, mags, beta, b_t,
                                               nkey, noise_var)
        return (chunked - jnp.float32(lr) * ghat,
                self._stats(beta, b_t, gn2, noise_var))

    # -- multi-round driver ------------------------------------------------

    def run_rounds(self, params, rounds: int, *, key, noise_var, p_max, lr,
                   grads=None, t0: int = 0):
        """Host loop over ``rounds`` jitted zoo rounds from absolute round
        ``t0`` (one compiled program, reused). Returns (params', list of
        host ZooStats)."""
        out = []
        for t in range(t0, t0 + rounds):
            if grads is not None:
                params, st = self.round_from_grads(
                    params, grads, t, key, noise_var, p_max, lr)
            else:
                params, st = self.round_gen(
                    params, t, key, noise_var, p_max, lr)
            out.append(jax.tree_util.tree_map(np.asarray, st))
        return params, out


def build_zoo_round(ob: OBCSAAConfig, D: int, mesh, **kw) -> ZooRound:
    """Build the shard_map'd zoo round programs for (ob, D, mesh)."""
    return ZooRound(ob, D, mesh, **kw)
