"""The engine's round body: eq. (3)–(14) as one traceable function
(DESIGN.md §11).

``build_engine`` closes an ``FLConfig`` + task (loss_fn, optimizer, D, U)
over three pure functions:

- ``fade_step``      — Gauss-Markov block-fading draw (core/channel.py;
                       Rayleigh marginal — the paper's §V model, replacing
                       the old host loop's half-normal ``np.abs(normal)``)
- ``schedule``       — P2 inside the trace: closed-form ``all``, the
                       vectorized greedy prefix solver, or the scan-safe
                       batched ADMM (repro.sched, DESIGN.md §10)
- ``round_given_schedule`` / ``full_round`` — local gradients (eq. 3),
                       optional error-feedback correction, compress +
                       MAC + decode (eq. 6-13, repro.core.obcsaa /
                       repro.decode) and the model update (eq. 14)

``full_round`` is the ``lax.scan`` body; the host reference loop in
``fl/rounds.py`` calls the SAME ``fade_step``/``schedule``/
``round_given_schedule`` functions one round at a time, which is what
makes the engine ≡ host-loop parity bitwise (tests/test_engine.py).

PRNG discipline: round t of an arm uses ``fold_in(arm.key, t)``, folded
again with 0 for the channel draw and 1 for the receiver AWGN — identical
key trees in scan and host execution.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel as chan
from repro.core.obcsaa import simulate_round
from repro.core.sparsify import (flatten_pytree, topk_sparsify,
                                 topk_sparsify_bisect)
from repro.engine.config import ENGINE_SCHEDULERS, FLConfig
from repro.engine.state import Arms, EngineState, RoundStats
from repro.optim.optimizers import ef_step
from repro.sched.admm import AdmmDuals, admm_solve_batched_jit
from repro.sched.greedy import greedy_solve_batched
from repro.sched.problem import BatchedProblem
from repro.theory.bounds import error_budget

_FADE_INIT_FOLD = 0x7FADE   # fold_in tag for the stationary t=0 fade draw


def budget_geometry(ob, D: int):
    """(n_chunks, S_eff, κ_eff) of the block-diagonal Φ at dimension D —
    the Theorem-1 budget geometry (DESIGN.md §4/§12): the chunked operator
    measures n_chunks·S_c symbols of an (up to) n_chunks·κ_c-sparse
    vector. Shared by the engine round body and the sharded zoo round
    (engine/zoo.py, DESIGN.md §14) so both report the same eq. 19 bound."""
    n_chunks = -(-D // ob.chunk)
    return n_chunks, n_chunks * ob.measure, min(n_chunks * ob.topk, D)


class EngineFns(NamedTuple):
    """The built round functions + static geometry."""
    init_state: Callable    # (params, arm) -> EngineState
    fade_step: Callable     # (fade, key) -> (h, fade')
    # (h, k_weights, noise_var, p_max, duals=None) -> (β, b_t, duals')
    # duals' is the exit AdmmDuals when FLConfig.sched_warm_duals is
    # active, else None — the carry leaf stays fixed per build
    schedule: Callable
    round_given_schedule: Callable
    full_round: Callable    # (state, arm, worker_data, k_weights, t)
    D: int
    U: int


def stacked_grads(loss_fn, params, stacked_data):
    """vmap of the per-worker full-batch gradient (eq. 3), flattened to
    (U, D) — the same ops as ``fl.worker.stacked_local_gradients`` (kept
    separate from ``repro.fl`` to break the wrapper→engine import cycle)."""
    def one(data):
        g = jax.grad(lambda p: loss_fn(p, data))(params)
        return flatten_pytree(g)[0]

    return jax.vmap(one)(stacked_data)


def perfect_aggregate(grads_flat, k_weights, beta):
    """Error-free weighted mean (paper's "perfect aggregation" bench)."""
    w = (k_weights * beta)[:, None]
    return jnp.sum(grads_flat * w, axis=0) / jnp.maximum(
        jnp.sum(k_weights * beta), 1e-12)


def topk_aa_aggregate(grads_flat, k_weights, beta, b_t, kappa, noise_var,
                      key):
    """Sparsified analog aggregation (no CS, no 1-bit): workers transmit
    their top-κ gradients directly; AWGN at the PS."""
    sp, _ = topk_sparsify(grads_flat, kappa)
    w = (k_weights * beta * b_t)[:, None]
    y = jnp.sum(sp * w, axis=0)
    y = y + chan.draw_noise(key, y.shape, noise_var)
    return y / jnp.maximum(jnp.sum(k_weights * beta) * b_t, 1e-12)


def build_engine(cfg: FLConfig, loss_fn: Callable, opt, D: int, U: int,
                 unflatten: Callable) -> EngineFns:
    """Close the static experiment config over the round functions.

    ``ob.packed`` flows through unchanged: the scan round body's compress
    emits uint32 sign words and the MAC unpacks them to the identical ±1
    floats (DESIGN.md §13), so packed engine sweeps are bit-for-bit equal
    to f32 sweeps (tests/test_packed.py). Validated here so a bad geometry
    fails at build time, not inside a traced round."""
    ob = cfg.obcsaa
    if ob.packed and cfg.aggregator == "obcsaa" and ob.measure % 32:
        raise ValueError(
            f"build_engine: packed 1-bit codec needs S_c % 32 == 0, got "
            f"measure={ob.measure} (DESIGN.md §13)")
    n_chunks, s_eff, kappa_eff = budget_geometry(ob, D)
    pad = n_chunks * ob.chunk - D
    warm = cfg.aggregator == "obcsaa" and ob.warm_start
    ef = cfg.error_feedback
    rho = jnp.float32(cfg.channel_rho)
    scfg = cfg.sched_cfg
    probe = cfg.probe_agg_error
    # Eq. 19 models the 1-bit CS pipeline, so the budget is only emitted
    # for the obcsaa aggregator (None leaf otherwise — fixed per build)
    track_bound = cfg.aggregator == "obcsaa"
    # Dual warm-starting only applies where ADMM actually runs per round
    warm_duals = (cfg.sched_warm_duals and cfg.aggregator != "perfect"
                  and cfg.scheduler in ("admm_batched", "admm_batched_jit"))

    def init_state(params, arm: Arms) -> EngineState:
        _, fade0 = chan.draw_fades(
            jax.random.fold_in(arm.key, _FADE_INIT_FOLD), (U,))
        return EngineState(
            params=params, opt_state=opt.init(params), fade=fade0,
            prev_beta=-jnp.ones((U,), jnp.float32),
            decode_x0=jnp.zeros((n_chunks, ob.chunk)) if warm else None,
            residual=jnp.zeros((U, D)) if ef else None,
            sched_duals=AdmmDuals.zeros((U,)) if warm_duals else None)

    def fade_step(fade, key):
        return chan.draw_fades(key, rho=rho, prev=fade)

    def schedule(h, k_weights, noise_var, p_max, duals=None):
        """P2 for one round's channels, inside the trace (B = 1).
        ``duals`` (a (U,)-leaf ``AdmmDuals`` | None) warm-starts the ADMM
        multipliers from the previous round's schedule; the returned
        triple carries the exit duals back when warm-starting is active
        (None otherwise, so the scan carry leaf is fixed per build)."""
        bp = BatchedProblem.from_arrays(
            h[None], k_weights[None], p_max, noise_var, D=D, S=ob.measure,
            kappa=ob.topk, const=cfg.const)
        duals_out = None
        if cfg.scheduler == "all":
            beta = jnp.ones_like(bp.h)
            b_t = bp.optimal_bt(beta)
        elif cfg.scheduler == "greedy_batched":
            beta, b_t, _ = greedy_solve_batched(bp, scfg)
        elif cfg.scheduler in ("admm_batched", "admm_batched_jit"):
            if warm_duals and duals is not None:
                d1 = jax.tree_util.tree_map(lambda l: l[None], duals)
                beta, b_t, _, info = admm_solve_batched_jit(
                    bp, scfg, duals=d1, return_duals=True)
                duals_out = jax.tree_util.tree_map(lambda l: l[0],
                                                   info.duals)
            else:
                beta, b_t, _ = admm_solve_batched_jit(bp, scfg)
        else:
            raise ValueError(
                f"scheduler {cfg.scheduler!r} cannot run inside the "
                f"engine scan (jittable: {ENGINE_SCHEDULERS}); use the "
                "host reference path")
        return beta[0], b_t[0], duals_out

    def _ef_sparse_approx(corrected):
        """approx_fn for ``optim.ef_step``: per-chunk top-κ of the padded
        corrected gradient. The selection follows ``ob.spmd_topk`` like
        the compression core: bisection thresholds are the scan/SPMD-
        native path (sort lowers to an XLA CPU/GSPMD-hostile full sort;
        DESIGN.md §9). Returns (sparse (U, D_pad), its unpadded view) —
        the residual accumulates exactly what the top-κ dropped."""
        gp = jnp.pad(corrected, ((0, 0), (0, pad)))
        gc = gp.reshape(gp.shape[0], -1, ob.chunk)
        if ob.spmd_topk:
            sp, _ = topk_sparsify_bisect(gc, ob.topk,
                                         iters=ob.bisect_iters)
        else:
            sp, _ = topk_sparsify(gc, ob.topk)
        sp = sp.reshape(gp.shape)
        return sp, sp[:, :D]

    def ef_split(grads, residual):
        """EF correction + residual update via the shared ``optim.ef_step``
        (one Stich-et-al implementation repo-wide, DESIGN.md §17).
        Returns (corrected, residual', sparse (U, D_pad)) — the sparse
        vector IS sparse_κ of what obcsaa transmits, so the compressor
        consumes it directly instead of re-thresholding (DESIGN.md §11)."""
        sp, new_residual, corrected = ef_step(grads, residual,
                                              _ef_sparse_approx)
        return corrected, new_residual, sp

    def round_given_schedule(state: EngineState, arm: Arms, worker_data,
                             k_weights, t, h, fade, beta, b_t,
                             sched_duals=None):
        """Eq. 3 → 6-7 → 10 → 13 → 43 → 14 for one round, with the
        schedule already decided (the host path injects β from the
        registry here; the engine computes it in ``full_round``).
        ``sched_duals`` is the exit-multiplier state of the β solve,
        stored in the carry for the next round's warm start (must be an
        ``AdmmDuals`` whenever ``sched_warm_duals`` built the carry with
        one — the scan structure is fixed per build)."""
        grads = stacked_grads(loss_fn, state.params, worker_data)
        residual = state.residual
        presparse = False
        if ef:
            grads, residual, sparse = ef_split(grads, residual)
        dense = grads          # probe target: pre-compression gradients
        if ef and cfg.aggregator == "obcsaa":
            # fused EF: the residual split's sparse_κ IS what obcsaa
            # transmits — skip the second selection (DESIGN.md §11)
            grads, presparse = sparse, True
        x0 = state.decode_x0
        if warm:
            # schedule change -> reset warm-start state (DESIGN.md §9);
            # masked where instead of the old host np.array_equal sync
            changed = jnp.any(beta != state.prev_beta)
            x0 = jnp.where(changed, jnp.zeros_like(x0), x0)
        k_noise = jax.random.fold_in(jax.random.fold_in(arm.key, t), 1)
        if cfg.aggregator == "perfect":
            ghat = perfect_aggregate(grads, k_weights, beta)
        elif cfg.aggregator == "topk_aa":
            ghat = topk_aa_aggregate(grads, k_weights, beta, b_t,
                                     cfg.topk_dense, arm.noise_var,
                                     k_noise)
        elif cfg.aggregator == "obcsaa":
            ghat, diag = simulate_round(ob, grads, k_weights, beta, b_t,
                                        h, k_noise, decode_x0=x0,
                                        noise_var=arm.noise_var,
                                        presparsified=presparse)
            if warm:
                x0 = diag["decode_xhat"]
        else:
            raise ValueError(f"unknown aggregator {cfg.aggregator!r}")
        params, opt_state = opt.update(unflatten(ghat[:D]),
                                       state.opt_state, state.params,
                                       arm.lr)
        new_state = EngineState(params=params, opt_state=opt_state,
                                fade=fade, prev_beta=beta, decode_x0=x0,
                                residual=residual, sched_duals=sched_duals)
        # predicted Theorem-1 budget at this round's operating point
        # (repro.theory, DESIGN.md §12) — pure closed-form scalar math on
        # (β, b_t, σ²), no effect on the training dataflow above
        budget = None
        if track_bound:
            budget = error_budget(cfg.const, D=D, S=s_eff,
                                  kappa=kappa_eff, beta=beta,
                                  k_weights=k_weights, b_t=b_t,
                                  noise_var=arm.noise_var)
        agg_err = None
        if probe:
            # measured ‖ĝ−ḡ‖²: the decoded estimate against the
            # error-free weighted mean over the scheduled cohort — the
            # quantity eq. (19) bounds. Static flag: off, the trace is
            # the pre-probe engine (DESIGN.md §12 measure-zero contract)
            ideal = perfect_aggregate(dense, k_weights, beta)
            agg_err = jnp.sum((ghat[:D] - ideal) ** 2)
        stats = RoundStats(n_scheduled=jnp.sum(beta).astype(jnp.int32),
                           b_t=jnp.asarray(b_t, jnp.float32),
                           budget=budget, agg_err=agg_err)
        return new_state, stats

    def full_round(state: EngineState, arm: Arms, worker_data, k_weights,
                   t):
        """The scan body: fade draw + P2 + the full round update."""
        k_t = jax.random.fold_in(arm.key, t)
        h, fade = fade_step(state.fade, jax.random.fold_in(k_t, 0))
        if cfg.aggregator == "perfect":
            beta = jnp.ones((U,), jnp.float32)
            b_t = jnp.float32(1.0)
            duals = None
        else:
            beta, b_t, duals = schedule(h, k_weights, arm.noise_var,
                                        arm.p_max, state.sched_duals)
        return round_given_schedule(state, arm, worker_data, k_weights, t,
                                    h, fade, beta, b_t, duals)

    return EngineFns(init_state=init_state, fade_step=fade_step,
                     schedule=schedule,
                     round_given_schedule=round_given_schedule,
                     full_round=full_round, D=D, U=U)
