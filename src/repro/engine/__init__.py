"""repro.engine — device-resident multi-round FL experiment engine
(DESIGN.md §11).

The paper's full round (eq. 3 local gradients → eq. 6-7 compress → eq. 10
power scaling → eq. 13 MAC+AWGN → eq. 43 decode → eq. 14 update) as a
single jitted ``lax.scan`` over rounds, chunked at the eval cadence, with
an ``Arms`` vmap axis batching experiment arms (seeds × SNR × P^Max × lr)
into one compiled program. ``fl/rounds.py:FederatedTrainer`` is the thin
host wrapper; benchmarks and sweeps call ``run_sweep`` directly.

Layering: imports ``repro.core`` (compression/channel/analysis),
``repro.sched`` (jittable P2 solvers), ``repro.decode`` (via obcsaa) and
``repro.optim`` — never ``repro.fl``, which sits above it.
"""
from repro.engine.config import ENGINE_SCHEDULERS, FLConfig
from repro.engine.core import (EngineFns, build_engine, perfect_aggregate,
                               stacked_grads, topk_aa_aggregate)
from repro.engine.runner import (EngineRun, chunk_spans, eval_points,
                                 run_sweep)
from repro.engine.state import (Arms, EngineState, RoundStats,
                                SweepCheckpoint, make_arms, n_arms,
                                single_arm)
from repro.engine.zoo import ZooRound, ZooStats, build_zoo_round

__all__ = [
    "Arms", "ENGINE_SCHEDULERS", "EngineFns", "EngineRun", "EngineState",
    "FLConfig", "RoundStats", "SweepCheckpoint", "ZooRound", "ZooStats",
    "build_engine", "build_zoo_round", "chunk_spans", "eval_points",
    "make_arms", "n_arms", "perfect_aggregate", "run_sweep", "single_arm",
    "stacked_grads", "topk_aa_aggregate",
]
