"""repro.engine.zoo_train — REAL sharded backward passes at zoo scale
(DESIGN.md §16) with stateful optimization carries (DESIGN.md §17).

engine/zoo.py proves the ≥1B-parameter compress→MAC→decode→update round
but drives it with surrogate gradients; this module closes the gap: the
genuine eq. 3 local gradients of a scanned-stacked-layer model, computed
parameter-sharded on the same workers×model mesh, flow into the SAME
round tail with nothing dense at full D ever replicated and zero layout
communication between the backward pass and the compressor.

The scheme (one ``jax.shard_map`` program over the whole mesh):

* The master lives as the zoo round's chunked ``(n_chunks, D_c)`` f32
  array, but its flat order is the :class:`~repro.dist.flat_layout
  .FlatShardLayout` model-major sharded-flat order: section m holds the
  m-th model-axis slice of every leaf. Device (worker d, model m) owns
  chunk rows ``m·n_half + d·n_local`` — exactly the slice of section m
  its own backward pass produces.
* Per round, each device casts its master block to the compute dtype and
  all-gathers over the WORKER axes only — materializing its model
  section, never full D — then views it as per-leaf weight shards by
  local reshapes (``section_to_tree``).
* The forward/backward is *redundant over the model axis*: every device
  in a worker column runs the worker's full loss on the worker's batch,
  resolving weight shards to full per-layer weights one scan step at a
  time through ``lm_forward``'s ``layer_resolver`` hook (non-stacked
  leaves — embedding, norms, shared blocks — are resolved once up
  front). The resolver's collective is ``collectives.replicated_gather``,
  whose adjoint is a LOCAL slice: replicated compute means replicated
  cotangents, so no cross-device float reduction exists anywhere in the
  backward and the round stays bitwise mesh-invariant. Remat policy
  (``TrainConfig.remat_policy``) bounds activation memory: with "full",
  per-layer gathered weights are recomputed, not saved.
* The resulting cotangents have exactly the shard shapes of
  ``section_to_tree``; flattening them back (``tree_to_section``) IS this
  device's (n_half, D_c) gradient block — grads enter ``compress_chunks``
  already in the layout the compressor consumes, with no host round-trip
  and no gather to full D. The MAC/decode tail is inherited unchanged
  from :class:`~repro.engine.zoo.ZooRound`.

The round carry is a :class:`ZooTrainState` (DESIGN.md §17): next to the
master, momentum/adam moments live as FIRST-CLASS sharded carries in the
SAME model-major ``(n_chunks, D_c)`` chunk rows (``repro.optim``'s
``Optimizer.update`` is elementwise, so it steps the shard-local block
inside ``shard_map`` — nothing dense at full D is ever replicated), and
with ``error_feedback=True`` the per-worker Stich-et-al residual extends
to zoo scale as a ``(U, n_chunks, D_c)`` carry in the grads layout: each
device holds its worker's residual rows for its model section, corrects
its gradient block via the shared ``optim.ef_step``, and feeds the
resulting top-κ sparse vector straight into ``compress_chunks``'s fused
``presparsified`` path (no second selection, DESIGN.md §11).

:meth:`ZooTrainRound.reference_round_train` is the jitted single-device
oracle (full params from ``master_to_tree``, identical op chain — EF
correction, compression, MAC, decode, optimizer update — with the
collectives replaced by their local stand-ins) — the bitwise parity
target of tests/test_zoo_train.py covers masters, moments, AND residuals.
:meth:`ZooTrainRound.run_sweep` lifts the multi-arm grid on top: one
jitted ``scan`` over rounds of ``lax.map`` over arms, so arms ×
zoo-scale params compose into one program. :meth:`save_state` /
:meth:`restore_state` checkpoint the FULL carry (master + moments +
residuals) through ``repro.checkpoint``'s template-strict atomic step
dirs, so a mid-sweep restore resumes bit-for-bit.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.obcsaa import OBCSAAConfig, compress_chunks
from repro.core.sparsify import topk_sparsify, topk_sparsify_bisect
from repro.dist import collectives as coll
from repro.dist.flat_layout import FlatShardLayout
from repro.dist.sharding import STACKED_KEYS, param_shard_dims
from repro.engine.zoo import ZooRound, ZooStats
from repro.launch.mesh import num_workers
from repro.optim import optimizers as optim


class ZooTrainStats(NamedTuple):
    """ZooStats plus the mean local training loss (host-visible)."""
    loss: jnp.ndarray
    n_scheduled: jnp.ndarray
    b_t: jnp.ndarray
    ghat_norm: jnp.ndarray
    budget: object


class ZooTrainState(NamedTuple):
    """The zoo-train round carry (DESIGN.md §17).

    ``master``: (n_chunks, D_c) f32 in the sharded-flat layout.
    ``opt``: optimizer moments over the SAME chunk rows — ``()`` for sgd,
    a (n_chunks, D_c) f32 array for momentum, ``{"m", "v", "t"}`` for
    adam — sharded exactly like the master (scalars replicate).
    ``residual``: per-worker EF residual (U, n_chunks, D_c) f32 in the
    grads layout, or None when the round runs without error feedback.
    The leaf structure is FIXED per round build (like ``EngineState``),
    so jitted programs never retrace on the carry."""
    master: jnp.ndarray
    opt: Any
    residual: Optional[jnp.ndarray]


def _with_loss(st: ZooStats, loss) -> ZooTrainStats:
    return ZooTrainStats(loss=loss, n_scheduled=st.n_scheduled, b_t=st.b_t,
                         ghat_norm=st.ghat_norm, budget=st.budget)


class ZooTrainRound(ZooRound):
    """Zoo round whose gradients come from a real sharded backward pass.

    ``model``: a ``repro.models.registry.Model`` whose params pytree is a
    dict (stacked layer collections under ``dist.sharding.STACKED_KEYS``).
    ``optimizer``: a name from ``repro.optim.optimizers.OPTIMIZERS``
    (sgd | momentum | adam); moments become sharded carry leaves next to
    the master. ``error_feedback`` adds the per-worker residual carry
    (DESIGN.md §17). Inherits the surrogate/array-fed programs, layout
    helpers, and the MAC/decode tail from :class:`ZooRound`; adds
    ``round_train`` / ``grads_in_layout`` / ``reference_round_train`` /
    ``run_sweep``. Programs are built lazily per batch structure."""

    def __init__(self, model, mesh, ob: OBCSAAConfig, *,
                 scheduler: str = "all", const=None, sched_cfg=None,
                 block_chunks: int = 64, compute_dtype=jnp.bfloat16,
                 remat="full", optimizer: str = "sgd", opt_kwargs=None,
                 error_feedback: bool = False):
        self.model = model
        self.compute_dtype = compute_dtype
        self.remat = remat
        self.optimizer_name = optimizer
        self.optimizer = optim.make(optimizer, **(opt_kwargs or {}))
        self.error_feedback = bool(error_feedback)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if not isinstance(shapes, dict):
            raise TypeError("zoo-train expects a dict params pytree, got "
                            f"{type(shapes)}")
        # gran aligns n_half to workers x block_chunks: every device owns a
        # whole number of FULL decode blocks, so block_dec == block_chunks
        # at any D instead of degenerating to a tiny divisor of an
        # unlucky n_local (the ≥1B decode would otherwise crawl through
        # thousands of 1-row lax.map steps)
        self.layout = FlatShardLayout.build(
            shapes, mesh, chunk=ob.chunk,
            gran=num_workers(mesh) * block_chunks)
        self._dims_tree = param_shard_dims(shapes, mesh)
        super().__init__(ob, self.layout.D, mesh, scheduler=scheduler,
                         const=const, sched_cfg=sched_cfg,
                         block_chunks=block_chunks,
                         n_chunks=self.layout.n_chunks)
        # moments live in the master's own (n_chunks, D_c) rows: the
        # optimizer update is elementwise, so the shard-local block update
        # inside shard_map IS the global update (DESIGN.md §17)
        self._opt_shapes = jax.eval_shape(
            self.optimizer.init,
            jax.ShapeDtypeStruct((self.n_chunks, ob.chunk), jnp.float32))
        # optimizer-update block rows, chosen from the MESH-side local row
        # count so the mesh body (n_local rows) and the oracle (n_chunks
        # rows) share one loop-body shape at a trip count >= 2 on both
        # sides — a single-trip map is simplified away and its body
        # re-fused into the surrounding program, un-pinning the update
        # (see _opt_update_blocks)
        self.block_opt = next(
            (x for x in range(min(self.block_dec,
                                  max(self.n_local // 2, 1)), 0, -1)
             if self.n_local % x == 0), 1)
        # per-layer gather dims for each stacked collection, keyed by the
        # per-layer treedef the scan body sees (stacked dim 0 sliced off,
        # so every stacked leaf's gather dim shifts down by one)
        self._resolver_dims = {}
        for key in STACKED_KEYS:
            if key in shapes:
                dleaves, dtd = jax.tree_util.tree_flatten(
                    self._dims_tree[key])
                self._resolver_dims[dtd] = [max(d - 1, -1) for d in dleaves]
        self._programs = {}

    # -- weight resolution --------------------------------------------------

    def _gather_leaf(self, x, dim: int):
        if self.n_model == 1 or dim < 0:
            return x
        return coll.replicated_gather(("model",), self.n_model, dim=dim)(x)

    def _layer_resolver(self, lp):
        """Shard -> full weights for one scanned layer (inside the scan
        body and the remat boundary)."""
        leaves, td = jax.tree_util.tree_flatten(lp)
        dims = self._resolver_dims.get(td)
        if dims is None:
            raise KeyError(
                f"zoo-train layer resolver saw an unknown per-layer "
                f"structure {td}; stacked collections must be registered "
                f"under dist.sharding.STACKED_KEYS {STACKED_KEYS}")
        return jax.tree_util.tree_unflatten(
            td, [self._gather_leaf(x, d) for x, d in zip(leaves, dims)])

    def _materialize(self, p_shards):
        """Resolve NON-stacked leaves to full weights up front; stacked
        collections stay sharded for the per-layer resolver."""
        out = {}
        for key, sub in p_shards.items():
            if key in STACKED_KEYS:
                out[key] = sub
            else:
                out[key] = jax.tree_util.tree_map(
                    self._gather_leaf, sub, self._dims_tree[key])
        return out

    def _local_loss_and_grads(self, pl, batch_u):
        """This device's loss + (n_half, D_c) gradient block, from its
        local master block ``pl`` — the heart of the tentpole."""
        sect = coll.all_gather(pl.astype(self.compute_dtype), self.waxes,
                               tiled=True)
        p_shards = self.layout.section_to_tree(sect)

        def loss_of(p_shards):
            loss, _ = self.model.loss_fn(
                self._materialize(p_shards), batch_u, remat=self.remat,
                layer_resolver=self._layer_resolver
                if self._resolver_dims else None)
            return loss

        loss, g_shards = jax.value_and_grad(loss_of)(p_shards)
        return loss, self.layout.tree_to_section(g_shards)

    def _sparse_approx(self, corrected):
        """approx_fn for ``optim.ef_step``: per-chunk top-κ of the
        corrected gradient chunks, selection following ``ob.spmd_topk``
        like the compression core — the sparse vector is BOTH the lossy
        approximation the residual accumulates against and what the
        compressor transmits (fused presparsified path, DESIGN.md §11)."""
        ob = self.ob
        if ob.spmd_topk:
            sp, _ = topk_sparsify_bisect(corrected, ob.topk,
                                         iters=ob.bisect_iters)
        else:
            sp, _ = topk_sparsify(corrected, ob.topk)
        return sp, sp

    def _compress_blocks(self, g_sect):
        """compress_chunks over (n_half, D_c) in block_chunks blocks (cast
        to f32 per block — the section itself stays in compute dtype)."""
        ob, n_half = self.ob, self.n_half
        nb = n_half // self.block
        signs, mags = jax.lax.map(
            lambda gb: compress_chunks(ob, gb.astype(jnp.float32), None),
            g_sect.reshape(nb, self.block, ob.chunk))
        return signs.reshape((n_half,) + signs.shape[2:]), \
            mags.reshape(n_half)

    def _compress_blocks_ef(self, g_sect, res_u):
        """EF-corrected compression over (n_half, D_c) in the same
        block_chunks blocks: per block, ``optim.ef_step`` corrects the
        f32 gradient chunks with this worker's residual rows, the top-κ
        sparse vector goes straight into the fused presparsified
        compressor, and the dropped remainder becomes the new residual
        (DESIGN.md §17). Returns (signs, mags, residual')."""
        ob, n_half = self.ob, self.n_half
        nb = n_half // self.block

        def one(args):
            gb, rb = args
            sp, r2, _ = optim.ef_step(gb.astype(jnp.float32), rb,
                                      self._sparse_approx)
            signs, mags = compress_chunks(ob, sp, None, presparsified=True)
            return signs, mags, r2

        signs, mags, res2 = jax.lax.map(
            one, (g_sect.reshape(nb, self.block, ob.chunk),
                  res_u.reshape(nb, self.block, ob.chunk)))
        return (signs.reshape((n_half,) + signs.shape[2:]),
                mags.reshape(n_half), res2.reshape(n_half, ob.chunk))

    def _opt_update_blocks(self, ghat, ol, pl, lr):
        """``Optimizer.update`` behind the same ``lax.map`` block-shape
        pinning as ``_decode_blocks``: the update is elementwise, but XLA
        fuses the adam step differently at the mesh's (n_local, D_c) and
        the oracle's (n_chunks, D_c) shapes inside the sweep's scan/map
        wrapper, drifting final ulps. A loop body of identical
        (block_dec, D_c) shape on both sides pins ONE compiled update
        program, keeping moments and master bitwise mesh-invariant
        (DESIGN.md §17). Row-shaped state leaves ride through the map in
        blocks; scalar leaves (adam's step counter) are closed over and
        deduplicated after the map (identical in every block)."""
        b = self.block_opt
        nb = pl.shape[0] // b
        leaves, td = jax.tree_util.tree_flatten(ol)
        rowwise = [getattr(l, "ndim", 0) == 2 for l in leaves]
        blocked = tuple(l.reshape(nb, b, -1)
                        for l, r in zip(leaves, rowwise) if r)

        def one(args):
            # the barriers keep XLA from fusing the update with its
            # producers/consumers — without them a trip-count-1 map (mesh
            # side at small n_local) is simplified away and the re-fused
            # update contracts differently from the oracle's
            gb, pb, sbs = jax.lax.optimization_barrier(args)
            cur, si = [], 0
            for r, l in zip(rowwise, leaves):
                if r:
                    cur.append(sbs[si])
                    si += 1
                else:
                    cur.append(l)
            st = jax.tree_util.tree_unflatten(td, cur)
            p2, st2 = self.optimizer.update(gb, st, pb, lr)
            l2 = jax.tree_util.tree_leaves(st2)
            return jax.lax.optimization_barrier(
                (p2, tuple(x for x, r in zip(l2, rowwise) if r),
                 tuple(x for x, r in zip(l2, rowwise) if not r)))

        p2, rows2, scal2 = jax.lax.map(
            one, (ghat.reshape(nb, b, -1), pl.reshape(nb, b, -1), blocked))
        rows2 = iter(x.reshape(pl.shape[0], -1) for x in rows2)
        scal2 = iter(x[0] for x in scal2)
        out = [next(rows2) if r else next(scal2) for r in rowwise]
        return p2.reshape(pl.shape), jax.tree_util.tree_unflatten(td, out)

    # -- state construction --------------------------------------------------

    def init_state(self, master) -> ZooTrainState:
        """Fresh round carry for a (n_chunks, D_c) master: zero moments in
        the master's own chunk rows, zero EF residual in the grads layout
        (when error feedback is on). Shard with :meth:`shard_state`."""
        res = (jnp.zeros((self.U, self.n_chunks, self.ob.chunk),
                         jnp.float32) if self.error_feedback else None)
        return ZooTrainState(master=master,
                             opt=self.optimizer.init(master), residual=res)

    def init_sweep_state(self, masters) -> ZooTrainState:
        """Arm-stacked carry for (A, n_chunks, D_c) masters (vmapped
        ``init_state``: per-arm moments/residuals, adam's step counter
        becomes an (A,) axis)."""
        A = int(masters.shape[0])
        opt = jax.vmap(self.optimizer.init)(masters)
        res = (jnp.zeros((A, self.U, self.n_chunks, self.ob.chunk),
                         jnp.float32) if self.error_feedback else None)
        return ZooTrainState(master=masters, opt=opt, residual=res)

    def state_template(self, arms: Optional[int] = None) -> ZooTrainState:
        """ShapeDtypeStruct pytree of the carry — the template-strict
        checkpoint restore target (moments + residuals included,
        DESIGN.md §17). ``arms``: arm-stacked sweep carry when set."""
        lead = () if arms is None else (int(arms),)
        sds = jax.ShapeDtypeStruct
        master = sds(lead + (self.n_chunks, self.ob.chunk), jnp.float32)
        opt = jax.tree_util.tree_map(
            lambda l: sds(lead + tuple(l.shape), l.dtype),
            self._opt_shapes)
        res = (sds(lead + (self.U, self.n_chunks, self.ob.chunk),
                   jnp.float32) if self.error_feedback else None)
        return ZooTrainState(master=master, opt=opt, residual=res)

    def state_shardings(self, arms: Optional[int] = None) -> ZooTrainState:
        """NamedSharding pytree matching :meth:`state_template`: master
        and 2-d moments in the model-major master spec, scalars (adam's
        step counter) replicated, residual in the grads spec."""
        lead = (None,) if arms is not None else ()

        def ns(spec):
            return NamedSharding(self.mesh, P(*lead, *spec))

        opt = jax.tree_util.tree_map(
            lambda l: ns(self.spec) if l.ndim == 2 else ns(()),
            self._opt_shapes)
        res = ns(self.grads_spec) if self.error_feedback else None
        return ZooTrainState(master=ns(self.spec), opt=opt, residual=res)

    def shard_state(self, state: ZooTrainState,
                    arms: Optional[int] = None) -> ZooTrainState:
        """device_put every carry leaf onto its mesh sharding."""
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(jnp.asarray(x), s),
            state, self.state_shardings(arms))

    def as_state(self, state) -> ZooTrainState:
        """Accept a ZooTrainState or — for the stateless sgd/no-EF round
        only — a bare (n_chunks, D_c) master (or (A, n_chunks, D_c) arm
        stack), wrapped into the trivial carry."""
        if isinstance(state, ZooTrainState):
            return state
        if getattr(state, "ndim", None) in (2, 3):
            if self.optimizer_name == "sgd" and not self.error_feedback:
                return ZooTrainState(master=state, opt=(), residual=None)
            raise TypeError(
                f"zoo-train round built with "
                f"optimizer={self.optimizer_name!r}, "
                f"error_feedback={self.error_feedback} carries stateful "
                f"moments/residuals; pass the ZooTrainState from "
                f"init_state(master) instead of a bare master array "
                f"(DESIGN.md §17)")
        raise TypeError(
            f"zoo-train round expects a ZooTrainState or a bare "
            f"(n_chunks, D_c) master array, got {type(state).__name__}")

    def _check_state(self, state: ZooTrainState):
        """EF residual-geometry validation, eagerly at the host entry
        points — a wrong carry fails here naming the expected geometry,
        not as an opaque spec error inside shard_map."""
        res = state.residual
        want = (self.U, self.n_chunks, self.ob.chunk)
        if self.error_feedback:
            if res is None:
                raise ValueError(
                    f"ZooTrainRound(error_feedback=True): the round carry "
                    f"has no EF residual; error feedback needs the "
                    f"per-worker (U, n_chunks, D_c) = {want} residual "
                    f"carry in the grads layout — build the carry with "
                    f"init_state(master), or restore a checkpoint written "
                    f"with error feedback on (DESIGN.md §17)")
            shape = tuple(res.shape)[-3:]
            if shape != want:
                raise ValueError(
                    f"ZooTrainRound(error_feedback=True): EF residual has "
                    f"shape {tuple(res.shape)}, expected (U, n_chunks, "
                    f"D_c) = {want} — the residual lives in the same "
                    f"chunk rows as the master, one row block per worker "
                    f"(DESIGN.md §17)")
        elif res is not None:
            raise ValueError(
                "ZooTrainRound(error_feedback=False) got a carry WITH an "
                "EF residual; rebuild the round with error_feedback=True "
                "or drop the residual — silently ignoring it would break "
                "the EF convergence contract (DESIGN.md §17)")

    # -- program construction ----------------------------------------------

    def _batch_key(self, batch):
        return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                            for k, v in batch.items()))

    def batch_spec(self, batch):
        """Per-leaf PartitionSpec tree: leading (U) worker dim over the
        worker axes, replicated over model."""
        w = self.waxes if len(self.waxes) > 1 else self.waxes[0]
        return {k: P(w, *(None,) * (v.ndim - 1)) for k, v in batch.items()}

    def shard_batch(self, batch):
        """device_put a (U, ...)-stacked batch dict onto the mesh."""
        spec = self.batch_spec(batch)
        return {k: jax.device_put(
            jnp.asarray(v), NamedSharding(self.mesh, spec[k]))
            for k, v in batch.items()}

    def _fns(self, batch):
        key = self._batch_key(batch)
        if key in self._programs:
            return self._programs[key]
        waxes, n_half = self.waxes, self.n_half
        rep, sc = P(None), P()
        bspec = self.batch_spec(batch)
        ef = self.error_feedback
        opt_spec = jax.tree_util.tree_map(
            lambda l: self.spec if l.ndim == 2 else sc, self._opt_shapes)

        def model_idx():
            return (coll.axis_index(("model",))
                    if "model" in self.mesh.axis_names
                    else jnp.zeros((), jnp.int32))

        def body_core(pl, ol, res_u, bl, beta, b_t, noise_key, noise_var,
                      lr):
            """One device's round: backward → (EF-corrected) compress →
            MAC/decode → optimizer update on the local master block.
            ``res_u``: this worker's (n_half, D_c) residual rows, or
            None without EF."""
            widx = coll.axis_index(waxes)
            half0 = model_idx() * n_half
            batch_u = jax.tree_util.tree_map(lambda x: x[0], bl)
            loss, g_sect = self._local_loss_and_grads(pl, batch_u)
            if res_u is None:
                signs, mags = self._compress_blocks(g_sect)
                res2 = None
            else:
                signs, mags, res2 = self._compress_blocks_ef(g_sect, res_u)
            ghat, gn2 = self._mac_decode(signs, mags, beta, b_t, noise_key,
                                         noise_var, widx, half0, None)
            pl2, ol2 = self._opt_update_blocks(ghat, ol, pl, lr)
            loss_mean = coll.psum(loss, waxes) / jnp.float32(self.U)
            return pl2, ol2, res2, gn2, loss_mean

        if ef:
            def body_train(pl, ol, rl, bl, beta, b_t, nkey, nv, lr):
                pl2, ol2, res2, gn2, loss = body_core(
                    pl, ol, rl[0], bl, beta, b_t, nkey, nv, lr)
                return pl2, ol2, res2[None], gn2, loss

            sm_train = jax.shard_map(
                body_train, mesh=self.mesh,
                in_specs=(self.spec, opt_spec, self.grads_spec, bspec,
                          rep, sc, rep, sc, sc),
                out_specs=(self.spec, opt_spec, self.grads_spec, sc, sc),
                check_vma=False)
        else:
            def body_train(pl, ol, bl, beta, b_t, nkey, nv, lr):
                pl2, ol2, _, gn2, loss = body_core(
                    pl, ol, None, bl, beta, b_t, nkey, nv, lr)
                return pl2, ol2, gn2, loss

            sm_train = jax.shard_map(
                body_train, mesh=self.mesh,
                in_specs=(self.spec, opt_spec, bspec, rep, sc, rep, sc,
                          sc),
                out_specs=(self.spec, opt_spec, sc, sc), check_vma=False)

        def body_grads_out(pl, bl):
            batch_u = jax.tree_util.tree_map(lambda x: x[0], bl)
            loss, g_sect = self._local_loss_and_grads(pl, batch_u)
            return g_sect.astype(jnp.float32)[None], loss[None]

        wspec = self.grads_spec[0]
        sm_grads_out = jax.shard_map(
            body_grads_out, mesh=self.mesh,
            in_specs=(self.spec, bspec),
            out_specs=(self.grads_spec, P(wspec)), check_vma=False)

        def round_impl(state, bl, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = self._prologue(t, key, noise_var, p_max)
            nv, lrf = jnp.float32(noise_var), jnp.float32(lr)
            if ef:
                pl2, ol2, rl2, gn2, loss = sm_train(
                    state.master, state.opt, state.residual, bl, beta,
                    b_t, nkey, nv, lrf)
            else:
                pl2, ol2, gn2, loss = sm_train(
                    state.master, state.opt, bl, beta, b_t, nkey, nv, lrf)
                rl2 = None
            st2 = ZooTrainState(master=pl2, opt=ol2, residual=rl2)
            return st2, _with_loss(self._stats(beta, b_t, gn2, noise_var),
                                   loss)

        def ref_impl(state, bl, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = self._prologue(t, key, noise_var, p_max)
            cdt = self.compute_dtype
            chunked = state.master
            residual = state.residual
            p_full = self.layout.master_to_tree(chunked.astype(cdt))

            def one(u):
                batch_u = jax.tree_util.tree_map(lambda x: x[u], bl)

                def loss_of(p):
                    loss, _ = self.model.loss_fn(p, batch_u,
                                                 remat=self.remat)
                    return loss

                loss, g = jax.value_and_grad(loss_of)(p_full)
                gm = self.layout.tree_to_master(g, dtype=cdt)
                if residual is None:
                    signs, mags = compress_chunks(
                        self.ob, gm.astype(jnp.float32), None)
                    return loss, signs, mags
                # identical EF chain to the mesh body: shared ef_step,
                # fused presparsified compress (DESIGN.md §17)
                sp, r2, _ = optim.ef_step(gm.astype(jnp.float32),
                                          residual[u], self._sparse_approx)
                signs, mags = compress_chunks(self.ob, sp, None,
                                              presparsified=True)
                return loss, signs, mags, r2

            outs = jax.lax.map(one, jnp.arange(self.U, dtype=jnp.int32))
            if residual is None:
                losses, signs, mags = outs
                res2 = None
            else:
                losses, signs, mags, res2 = outs
            ghat, gn2 = self._reference_mac_decode(signs, mags, beta, b_t,
                                                   nkey, noise_var)
            chunked2, opt2 = self._opt_update_blocks(ghat, state.opt,
                                                     chunked,
                                                     jnp.float32(lr))
            st2 = ZooTrainState(master=chunked2, opt=opt2, residual=res2)
            return st2, _with_loss(self._stats(beta, b_t, gn2, noise_var),
                                   jnp.mean(losses))

        def ref_grads_impl(chunked, bl):
            cdt = self.compute_dtype
            p_full = self.layout.master_to_tree(chunked.astype(cdt))

            def one(u):
                batch_u = jax.tree_util.tree_map(lambda x: x[u], bl)

                def loss_of(p):
                    loss, _ = self.model.loss_fn(p, batch_u,
                                                 remat=self.remat)
                    return loss

                loss, g = jax.value_and_grad(loss_of)(p_full)
                return self.layout.tree_to_master(g, dtype=cdt).astype(
                    jnp.float32), loss

            g, losses = jax.lax.map(one, jnp.arange(self.U,
                                                    dtype=jnp.int32))
            return g, losses

        fns = {
            "round_train": jax.jit(round_impl),
            "round_impl": round_impl,
            "grads_in_layout": jax.jit(sm_grads_out),
            # oracles are jitted for the same reason as ZooRound's: eager
            # f32 fusion drifts final ulps vs the compiled sharded round
            "ref_train": jax.jit(ref_impl),
            "ref_impl": ref_impl,
            "ref_grads": jax.jit(ref_grads_impl),
        }
        self._programs[key] = fns
        return fns

    # -- public entry points -----------------------------------------------

    def round_train(self, state, batch, t, key, noise_var, p_max, lr):
        """One real-gradient round. ``state``: ZooTrainState from
        ``init_state``/``shard_state`` (a bare sharded (n_chunks, D_c)
        master is accepted for the stateless sgd/no-EF round); ``batch``:
        dict of (U, ...)-stacked arrays from ``shard_batch``. Returns
        (state', ZooTrainStats)."""
        state = self.as_state(state)
        self._check_state(state)
        return self._fns(batch)["round_train"](state, batch, t, key,
                                               noise_var, p_max, lr)

    def grads_in_layout(self, master, batch):
        """The real per-worker gradients as the sharded (U, n_chunks, D_c)
        array ``round_from_grads`` consumes — the debug/parity surface for
        "grads produced already in the compressor's layout". Returns
        (grads, per-worker losses)."""
        if isinstance(master, ZooTrainState):
            master = master.master
        return self._fns(batch)["grads_in_layout"](master, batch)

    def reference_round_train(self, state, batch, t, key, noise_var,
                              p_max, lr):
        """Single-device oracle of ``round_train`` (replicated inputs)."""
        state = self.as_state(state)
        self._check_state(state)
        return self._fns(batch)["ref_train"](state, batch, t, key,
                                             noise_var, p_max, lr)

    def reference_grads(self, chunked, batch):
        """Single-device oracle of ``grads_in_layout``."""
        if isinstance(chunked, ZooTrainState):
            chunked = chunked.master
        return self._fns(batch)["ref_grads"](chunked, batch)

    # -- params layout ------------------------------------------------------

    def chunk_params(self, params):
        """Params pytree -> (n_chunks, D_c) in the sharded-flat layout
        (overrides ZooRound's tail-padded flatten: the zoo-train order is
        model-major per-leaf-slice, DESIGN.md §16)."""
        return self.layout.tree_to_master(params)

    def params_from_master(self, chunked):
        """(n_chunks, D_c) -> full params pytree (checkpoint/eval
        interop)."""
        if isinstance(chunked, ZooTrainState):
            chunked = chunked.master
        return self.layout.master_to_tree(jnp.asarray(chunked))

    def unchunk(self, chunked):
        leaves = jax.tree_util.tree_leaves(self.params_from_master(chunked))
        return jnp.concatenate([x.reshape(-1) for x in leaves])

    # -- multi-arm sweep ----------------------------------------------------

    def _sweep_program(self, body, tag, batch, A: int, rounds: int, t0):
        """scan-over-rounds of lax.map-over-arms of ``body``, jitted and
        cached. The mesh sweep and its oracle are built from the SAME
        wrapper so their program structure matches — the wrapping itself
        changes XLA fusion inside the round body, so the bitwise parity
        contract is per-structure: jitted round ↔ jitted reference round,
        jitted sweep ↔ jitted reference sweep (DESIGN.md §16)."""
        def sweep_impl(states, bl, key, nv, pm, lr):
            def one_round(ss, t):
                def one_arm(args):
                    s, nv_a, pm_a, lr_a = args
                    return body(s, bl, t, key, nv_a, pm_a, lr_a)
                s2, st = jax.lax.map(one_arm, (ss, nv, pm, lr))
                return s2, st
            ts = t0 + jnp.arange(rounds, dtype=jnp.int32)
            return jax.lax.scan(one_round, states, ts)

        return self._programs.setdefault(
            (tag, self._batch_key(batch), A, rounds, int(t0)),
            jax.jit(sweep_impl))

    def run_sweep(self, states, batch, arms, rounds: int, *, key, t0=0):
        """Arms × rounds in ONE jitted program: ``lax.scan`` over rounds
        of ``lax.map`` over arms of the shard_map'd round body.

        ``states``: arm-stacked ZooTrainState from ``init_sweep_state``/
        ``shard_state(..., arms=A)`` (bare (A, n_chunks, D_c) masters are
        accepted for the stateless round, see ``shard_masters``);
        ``arms``: dict of (A,) f32 arrays ``noise_var`` / ``p_max`` /
        ``lr``. Returns (states', ZooTrainStats stacked (rounds, A))."""
        states = self.as_state(states)
        self._check_state(states)
        fns = self._fns(batch)
        A = int(arms["noise_var"].shape[0])
        jitted = self._sweep_program(fns["round_impl"], "sweep", batch, A,
                                     rounds, t0)
        return jitted(states, batch, key, arms["noise_var"],
                      arms["p_max"], arms["lr"])

    def reference_sweep(self, states, batch, arms, rounds: int, *, key,
                        t0=0):
        """Single-device oracle of ``run_sweep`` with the identical
        scan/map wrapping (replicated arm-stacked carry)."""
        states = self.as_state(states)
        self._check_state(states)
        fns = self._fns(batch)
        A = int(arms["noise_var"].shape[0])
        jitted = self._sweep_program(fns["ref_impl"], "ref_sweep", batch,
                                     A, rounds, t0)
        return jitted(states, batch, key, arms["noise_var"],
                      arms["p_max"], arms["lr"])

    def shard_masters(self, masters):
        """(A, n_chunks, D_c) arm-stacked masters: chunk axis model-major
        sharded exactly like a single master, arms replicated."""
        spec = P(None, *self.spec)
        return jax.device_put(jnp.asarray(masters),
                              NamedSharding(self.mesh, spec))

    # -- checkpointing -------------------------------------------------------

    def save_state(self, ckpt_dir: str, step: int, state: ZooTrainState,
                   t_next: int) -> str:
        """Snapshot the FULL round carry — master + optimizer moments +
        EF residuals — plus the absolute next-round index, one atomic
        step dir via ``repro.checkpoint`` (DESIGN.md §17). Round RNG and
        schedules fold the absolute round index, so no RNG state needs
        serializing for a bit-for-bit resume."""
        from repro import checkpoint
        host = jax.tree_util.tree_map(np.asarray, state)
        return checkpoint.save(ckpt_dir, step,
                               {"state": host,
                                "t_next": np.int32(t_next)})

    def restore_state(self, ckpt_dir: str, step: Optional[int] = None,
                      arms: Optional[int] = None):
        """(state, t_next) from ``step`` (default: latest), template-
        strict against :meth:`state_template` (leaf count, shapes, AND
        dtypes — moments restore dtype-strict) and device_put onto
        :meth:`state_shardings` — a carry saved on one mesh resumes on a
        differently-shaped one (mesh-elastic, DESIGN.md §14/§17).
        Returns None when the directory holds no steps yet."""
        from repro import checkpoint
        if step is None:
            step = checkpoint.latest_step(ckpt_dir)
            if step is None:
                return None
        like = {"state": self.state_template(arms),
                "t_next": jax.ShapeDtypeStruct((), jnp.int32)}
        shardings = {"state": self.state_shardings(arms),
                     "t_next": NamedSharding(self.mesh, P())}
        tree = checkpoint.restore(ckpt_dir, step, like,
                                  shardings=shardings)
        return tree["state"], int(tree["t_next"])

    # -- host driver --------------------------------------------------------

    def run_rounds_train(self, state, batch, rounds: int, *, key,
                         noise_var, p_max, lr, t0: int = 0,
                         ckpt_dir: Optional[str] = None,
                         ckpt_every: int = 0):
        """Host loop over jitted real-gradient rounds (one compiled
        program, reused) from absolute round ``t0``, optionally snapshot-
        ting the full carry every ``ckpt_every`` rounds. Returns
        (state', list of host ZooTrainStats)."""
        state = self.as_state(state)
        out = []
        for t in range(t0, t0 + rounds):
            state, st = self.round_train(state, batch, t, key, noise_var,
                                         p_max, lr)
            out.append(jax.tree_util.tree_map(np.asarray, st))
            if ckpt_dir and ckpt_every and (t + 1) % ckpt_every == 0:
                self.save_state(ckpt_dir, t + 1, state, t_next=t + 1)
        return state, out


def build_zoo_train_round(model, mesh, ob: OBCSAAConfig,
                          **kw) -> ZooTrainRound:
    """Build the sharded real-backward zoo round for (model, mesh, ob)."""
    return ZooTrainRound(model, mesh, ob, **kw)
