"""repro.engine.zoo_train — REAL sharded backward passes at zoo scale
(DESIGN.md §16).

engine/zoo.py proves the ≥1B-parameter compress→MAC→decode→update round
but drives it with surrogate gradients; this module closes the gap: the
genuine eq. 3 local gradients of a scanned-stacked-layer model, computed
parameter-sharded on the same workers×model mesh, flow into the SAME
round tail with nothing dense at full D ever replicated and zero layout
communication between the backward pass and the compressor.

The scheme (one ``jax.shard_map`` program over the whole mesh):

* The master lives as the zoo round's chunked ``(n_chunks, D_c)`` f32
  array, but its flat order is the :class:`~repro.dist.flat_layout
  .FlatShardLayout` model-major sharded-flat order: section m holds the
  m-th model-axis slice of every leaf. Device (worker d, model m) owns
  chunk rows ``m·n_half + d·n_local`` — exactly the slice of section m
  its own backward pass produces.
* Per round, each device casts its master block to the compute dtype and
  all-gathers over the WORKER axes only — materializing its model
  section, never full D — then views it as per-leaf weight shards by
  local reshapes (``section_to_tree``).
* The forward/backward is *redundant over the model axis*: every device
  in a worker column runs the worker's full loss on the worker's batch,
  resolving weight shards to full per-layer weights one scan step at a
  time through ``lm_forward``'s ``layer_resolver`` hook (non-stacked
  leaves — embedding, norms, shared blocks — are resolved once up
  front). The resolver's collective is ``collectives.replicated_gather``,
  whose adjoint is a LOCAL slice: replicated compute means replicated
  cotangents, so no cross-device float reduction exists anywhere in the
  backward and the round stays bitwise mesh-invariant. Remat policy
  (``TrainConfig.remat_policy``) bounds activation memory: with "full",
  per-layer gathered weights are recomputed, not saved.
* The resulting cotangents have exactly the shard shapes of
  ``section_to_tree``; flattening them back (``tree_to_section``) IS this
  device's (n_half, D_c) gradient block — grads enter ``compress_chunks``
  already in the layout the compressor consumes, with no host round-trip
  and no gather to full D. The MAC/decode/update tail is inherited
  unchanged from :class:`~repro.engine.zoo.ZooRound`.

:meth:`ZooTrainRound.reference_round_train` is the jitted single-device
oracle (full params from ``master_to_tree``, identical op chain with the
collectives replaced by their local stand-ins) — the bitwise parity
target of tests/test_zoo_train.py. :meth:`ZooTrainRound.run_sweep` lifts
the multi-arm grid on top: one jitted ``scan`` over rounds of ``lax.map``
over arms, so arms × zoo-scale params compose into one program.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.obcsaa import OBCSAAConfig, compress_chunks
from repro.dist import collectives as coll
from repro.dist.flat_layout import FlatShardLayout
from repro.dist.sharding import STACKED_KEYS, param_shard_dims
from repro.engine.zoo import ZooRound, ZooStats
from repro.launch.mesh import num_workers


class ZooTrainStats(NamedTuple):
    """ZooStats plus the mean local training loss (host-visible)."""
    loss: jnp.ndarray
    n_scheduled: jnp.ndarray
    b_t: jnp.ndarray
    ghat_norm: jnp.ndarray
    budget: object


def _with_loss(st: ZooStats, loss) -> ZooTrainStats:
    return ZooTrainStats(loss=loss, n_scheduled=st.n_scheduled, b_t=st.b_t,
                         ghat_norm=st.ghat_norm, budget=st.budget)


class ZooTrainRound(ZooRound):
    """Zoo round whose gradients come from a real sharded backward pass.

    ``model``: a ``repro.models.registry.Model`` whose params pytree is a
    dict (stacked layer collections under ``dist.sharding.STACKED_KEYS``).
    Inherits the surrogate/array-fed programs, layout helpers, and the
    MAC/decode/update tail from :class:`ZooRound`; adds
    ``round_train`` / ``grads_in_layout`` / ``reference_round_train`` /
    ``run_sweep``. Programs are built lazily per batch structure."""

    def __init__(self, model, mesh, ob: OBCSAAConfig, *,
                 scheduler: str = "all", const=None, sched_cfg=None,
                 block_chunks: int = 64, compute_dtype=jnp.bfloat16,
                 remat="full"):
        self.model = model
        self.compute_dtype = compute_dtype
        self.remat = remat
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if not isinstance(shapes, dict):
            raise TypeError("zoo-train expects a dict params pytree, got "
                            f"{type(shapes)}")
        # gran aligns n_half to workers x block_chunks: every device owns a
        # whole number of FULL decode blocks, so block_dec == block_chunks
        # at any D instead of degenerating to a tiny divisor of an
        # unlucky n_local (the ≥1B decode would otherwise crawl through
        # thousands of 1-row lax.map steps)
        self.layout = FlatShardLayout.build(
            shapes, mesh, chunk=ob.chunk,
            gran=num_workers(mesh) * block_chunks)
        self._dims_tree = param_shard_dims(shapes, mesh)
        super().__init__(ob, self.layout.D, mesh, scheduler=scheduler,
                         const=const, sched_cfg=sched_cfg,
                         block_chunks=block_chunks,
                         n_chunks=self.layout.n_chunks)
        # per-layer gather dims for each stacked collection, keyed by the
        # per-layer treedef the scan body sees (stacked dim 0 sliced off,
        # so every stacked leaf's gather dim shifts down by one)
        self._resolver_dims = {}
        for key in STACKED_KEYS:
            if key in shapes:
                dleaves, dtd = jax.tree_util.tree_flatten(
                    self._dims_tree[key])
                self._resolver_dims[dtd] = [max(d - 1, -1) for d in dleaves]
        self._programs = {}

    # -- weight resolution --------------------------------------------------

    def _gather_leaf(self, x, dim: int):
        if self.n_model == 1 or dim < 0:
            return x
        return coll.replicated_gather(("model",), self.n_model, dim=dim)(x)

    def _layer_resolver(self, lp):
        """Shard -> full weights for one scanned layer (inside the scan
        body and the remat boundary)."""
        leaves, td = jax.tree_util.tree_flatten(lp)
        dims = self._resolver_dims.get(td)
        if dims is None:
            raise KeyError(
                f"zoo-train layer resolver saw an unknown per-layer "
                f"structure {td}; stacked collections must be registered "
                f"under dist.sharding.STACKED_KEYS {STACKED_KEYS}")
        return jax.tree_util.tree_unflatten(
            td, [self._gather_leaf(x, d) for x, d in zip(leaves, dims)])

    def _materialize(self, p_shards):
        """Resolve NON-stacked leaves to full weights up front; stacked
        collections stay sharded for the per-layer resolver."""
        out = {}
        for key, sub in p_shards.items():
            if key in STACKED_KEYS:
                out[key] = sub
            else:
                out[key] = jax.tree_util.tree_map(
                    self._gather_leaf, sub, self._dims_tree[key])
        return out

    def _local_loss_and_grads(self, pl, batch_u):
        """This device's loss + (n_half, D_c) gradient block, from its
        local master block ``pl`` — the heart of the tentpole."""
        sect = coll.all_gather(pl.astype(self.compute_dtype), self.waxes,
                               tiled=True)
        p_shards = self.layout.section_to_tree(sect)

        def loss_of(p_shards):
            loss, _ = self.model.loss_fn(
                self._materialize(p_shards), batch_u, remat=self.remat,
                layer_resolver=self._layer_resolver
                if self._resolver_dims else None)
            return loss

        loss, g_shards = jax.value_and_grad(loss_of)(p_shards)
        return loss, self.layout.tree_to_section(g_shards)

    def _compress_blocks(self, g_sect):
        """compress_chunks over (n_half, D_c) in block_chunks blocks (cast
        to f32 per block — the section itself stays in compute dtype)."""
        ob, n_half = self.ob, self.n_half
        nb = n_half // self.block
        signs, mags = jax.lax.map(
            lambda gb: compress_chunks(ob, gb.astype(jnp.float32), None),
            g_sect.reshape(nb, self.block, ob.chunk))
        return signs.reshape((n_half,) + signs.shape[2:]), \
            mags.reshape(n_half)

    # -- program construction ----------------------------------------------

    def _batch_key(self, batch):
        return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                            for k, v in batch.items()))

    def batch_spec(self, batch):
        """Per-leaf PartitionSpec tree: leading (U) worker dim over the
        worker axes, replicated over model."""
        w = self.waxes if len(self.waxes) > 1 else self.waxes[0]
        return {k: P(w, *(None,) * (v.ndim - 1)) for k, v in batch.items()}

    def shard_batch(self, batch):
        """device_put a (U, ...)-stacked batch dict onto the mesh."""
        spec = self.batch_spec(batch)
        return {k: jax.device_put(
            jnp.asarray(v), NamedSharding(self.mesh, spec[k]))
            for k, v in batch.items()}

    def _fns(self, batch):
        key = self._batch_key(batch)
        if key in self._programs:
            return self._programs[key]
        waxes, n_half = self.waxes, self.n_half
        rep, sc = P(None), P()
        bspec = self.batch_spec(batch)

        def model_idx():
            return (coll.axis_index(("model",))
                    if "model" in self.mesh.axis_names
                    else jnp.zeros((), jnp.int32))

        def body_train(pl, bl, beta, b_t, noise_key, noise_var, lr):
            widx = coll.axis_index(waxes)
            half0 = model_idx() * n_half
            batch_u = jax.tree_util.tree_map(lambda x: x[0], bl)
            loss, g_sect = self._local_loss_and_grads(pl, batch_u)
            signs, mags = self._compress_blocks(g_sect)
            pl2, gn2 = self._mac_decode_update(
                pl, signs, mags, beta, b_t, noise_key, noise_var, lr,
                widx, half0, None)
            loss_mean = coll.psum(loss, waxes) / jnp.float32(self.U)
            return pl2, gn2, loss_mean

        def body_grads_out(pl, bl):
            batch_u = jax.tree_util.tree_map(lambda x: x[0], bl)
            loss, g_sect = self._local_loss_and_grads(pl, batch_u)
            return g_sect.astype(jnp.float32)[None], loss[None]

        sm_train = jax.shard_map(
            body_train, mesh=self.mesh,
            in_specs=(self.spec, bspec, rep, sc, rep, sc, sc),
            out_specs=(self.spec, sc, sc), check_vma=False)
        wspec = self.grads_spec[0]
        sm_grads_out = jax.shard_map(
            body_grads_out, mesh=self.mesh,
            in_specs=(self.spec, bspec),
            out_specs=(self.grads_spec, P(wspec)), check_vma=False)

        def round_impl(master, bl, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = self._prologue(t, key, noise_var, p_max)
            pl2, gn2, loss = sm_train(master, bl, beta, b_t, nkey,
                                      jnp.float32(noise_var),
                                      jnp.float32(lr))
            return pl2, _with_loss(self._stats(beta, b_t, gn2, noise_var),
                                   loss)

        def ref_impl(chunked, bl, t, key, noise_var, p_max, lr):
            t, beta, b_t, nkey = self._prologue(t, key, noise_var, p_max)
            cdt = self.compute_dtype
            p_full = self.layout.master_to_tree(chunked.astype(cdt))

            def one(u):
                batch_u = jax.tree_util.tree_map(lambda x: x[u], bl)

                def loss_of(p):
                    loss, _ = self.model.loss_fn(p, batch_u,
                                                 remat=self.remat)
                    return loss

                loss, g = jax.value_and_grad(loss_of)(p_full)
                gm = self.layout.tree_to_master(g, dtype=cdt)
                signs, mags = compress_chunks(
                    self.ob, gm.astype(jnp.float32), None)
                return loss, signs, mags

            losses, signs, mags = jax.lax.map(
                one, jnp.arange(self.U, dtype=jnp.int32))
            chunked2, st = self._reference_tail(
                chunked, signs, mags, beta, b_t, nkey, noise_var, lr)
            return chunked2, _with_loss(st, jnp.mean(losses))

        def ref_grads_impl(chunked, bl):
            cdt = self.compute_dtype
            p_full = self.layout.master_to_tree(chunked.astype(cdt))

            def one(u):
                batch_u = jax.tree_util.tree_map(lambda x: x[u], bl)

                def loss_of(p):
                    loss, _ = self.model.loss_fn(p, batch_u,
                                                 remat=self.remat)
                    return loss

                loss, g = jax.value_and_grad(loss_of)(p_full)
                return self.layout.tree_to_master(g, dtype=cdt).astype(
                    jnp.float32), loss

            g, losses = jax.lax.map(one, jnp.arange(self.U,
                                                    dtype=jnp.int32))
            return g, losses

        fns = {
            "round_train": jax.jit(round_impl),
            "round_impl": round_impl,
            "grads_in_layout": jax.jit(sm_grads_out),
            # oracles are jitted for the same reason as ZooRound's: eager
            # f32 fusion drifts final ulps vs the compiled sharded round
            "ref_train": jax.jit(ref_impl),
            "ref_impl": ref_impl,
            "ref_grads": jax.jit(ref_grads_impl),
        }
        self._programs[key] = fns
        return fns

    # -- public entry points -----------------------------------------------

    def round_train(self, master, batch, t, key, noise_var, p_max, lr):
        """One real-gradient round. ``master``: sharded (n_chunks, D_c)
        from ``shard_params(chunk_params(params))``; ``batch``: dict of
        (U, ...)-stacked arrays from ``shard_batch``. Returns
        (master', ZooTrainStats)."""
        return self._fns(batch)["round_train"](master, batch, t, key,
                                               noise_var, p_max, lr)

    def grads_in_layout(self, master, batch):
        """The real per-worker gradients as the sharded (U, n_chunks, D_c)
        array ``round_from_grads`` consumes — the debug/parity surface for
        "grads produced already in the compressor's layout". Returns
        (grads, per-worker losses)."""
        return self._fns(batch)["grads_in_layout"](master, batch)

    def reference_round_train(self, chunked, batch, t, key, noise_var,
                              p_max, lr):
        """Single-device oracle of ``round_train`` (replicated inputs)."""
        return self._fns(batch)["ref_train"](chunked, batch, t, key,
                                             noise_var, p_max, lr)

    def reference_grads(self, chunked, batch):
        """Single-device oracle of ``grads_in_layout``."""
        return self._fns(batch)["ref_grads"](chunked, batch)

    # -- params layout ------------------------------------------------------

    def chunk_params(self, params):
        """Params pytree -> (n_chunks, D_c) in the sharded-flat layout
        (overrides ZooRound's tail-padded flatten: the zoo-train order is
        model-major per-leaf-slice, DESIGN.md §16)."""
        return self.layout.tree_to_master(params)

    def params_from_master(self, chunked):
        """(n_chunks, D_c) -> full params pytree (checkpoint/eval
        interop)."""
        return self.layout.master_to_tree(jnp.asarray(chunked))

    def unchunk(self, chunked):
        leaves = jax.tree_util.tree_leaves(self.params_from_master(chunked))
        return jnp.concatenate([x.reshape(-1) for x in leaves])

    # -- multi-arm sweep ----------------------------------------------------

    def _sweep_program(self, body, tag, batch, A: int, rounds: int, t0):
        """scan-over-rounds of lax.map-over-arms of ``body``, jitted and
        cached. The mesh sweep and its oracle are built from the SAME
        wrapper so their program structure matches — the wrapping itself
        changes XLA fusion inside the round body, so the bitwise parity
        contract is per-structure: jitted round ↔ jitted reference round,
        jitted sweep ↔ jitted reference sweep (DESIGN.md §16)."""
        def sweep_impl(masters, bl, key, nv, pm, lr):
            def one_round(ms, t):
                def one_arm(args):
                    m, nv_a, pm_a, lr_a = args
                    return body(m, bl, t, key, nv_a, pm_a, lr_a)
                m2, st = jax.lax.map(one_arm, (ms, nv, pm, lr))
                return m2, st
            ts = t0 + jnp.arange(rounds, dtype=jnp.int32)
            return jax.lax.scan(one_round, masters, ts)

        return self._programs.setdefault(
            (tag, self._batch_key(batch), A, rounds, int(t0)),
            jax.jit(sweep_impl))

    def run_sweep(self, masters, batch, arms, rounds: int, *, key, t0=0):
        """Arms × rounds in ONE jitted program: ``lax.scan`` over rounds
        of ``lax.map`` over arms of the shard_map'd round body.

        ``masters``: (A, n_chunks, D_c) (see ``shard_masters``);
        ``arms``: dict of (A,) f32 arrays ``noise_var`` / ``p_max`` /
        ``lr``. Returns (masters', ZooTrainStats stacked (rounds, A))."""
        fns = self._fns(batch)
        A = int(arms["noise_var"].shape[0])
        jitted = self._sweep_program(fns["round_impl"], "sweep", batch, A,
                                     rounds, t0)
        return jitted(masters, batch, key, arms["noise_var"],
                      arms["p_max"], arms["lr"])

    def reference_sweep(self, masters, batch, arms, rounds: int, *, key,
                        t0=0):
        """Single-device oracle of ``run_sweep`` with the identical
        scan/map wrapping (replicated (A, n_chunks, D_c) masters)."""
        fns = self._fns(batch)
        A = int(arms["noise_var"].shape[0])
        jitted = self._sweep_program(fns["ref_impl"], "ref_sweep", batch,
                                     A, rounds, t0)
        return jitted(masters, batch, key, arms["noise_var"],
                      arms["p_max"], arms["lr"])

    def shard_masters(self, masters):
        """(A, n_chunks, D_c) arm-stacked masters: chunk axis model-major
        sharded exactly like a single master, arms replicated."""
        spec = P(None, *self.spec)
        return jax.device_put(jnp.asarray(masters),
                              NamedSharding(self.mesh, spec))

    # -- host driver --------------------------------------------------------

    def run_rounds_train(self, master, batch, rounds: int, *, key,
                         noise_var, p_max, lr, t0: int = 0):
        """Host loop over jitted real-gradient rounds (one compiled
        program, reused). Returns (master', list of host ZooTrainStats)."""
        out = []
        for t in range(t0, t0 + rounds):
            master, st = self.round_train(master, batch, t, key, noise_var,
                                          p_max, lr)
            out.append(jax.tree_util.tree_map(np.asarray, st))
        return master, out


def build_zoo_train_round(model, mesh, ob: OBCSAAConfig,
                          **kw) -> ZooTrainRound:
    """Build the sharded real-backward zoo round for (model, mesh, ob)."""
    return ZooTrainRound(model, mesh, ob, **kw)
