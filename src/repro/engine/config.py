"""FL experiment configuration — consumed by the device-resident engine
(DESIGN.md §11) and by the host reference loop in ``repro.fl.rounds``.

``FLConfig`` lives here (not in ``repro.fl``) because the engine is the
layer below the trainer: ``fl/rounds.py:FederatedTrainer`` is a thin host
wrapper over ``repro.engine`` and re-exports this class unchanged, so
existing ``from repro.fl import FLConfig`` call sites keep working.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.theory.bounds import AnalysisConstants
from repro.core.obcsaa import OBCSAAConfig
from repro.sched.config import SchedConfig

# Scheduler strings the engine can inline in its jitted round body
# (DESIGN.md §11). "admm_batched" maps onto the scan-safe
# ``admm_solve_batched_jit`` inside the engine; the host-compacted fleet
# solver keeps the name for registry callers. Everything else (enum and
# the NumPy reference oracles) runs through the host reference path.
ENGINE_SCHEDULERS = ("all", "greedy_batched", "admm_batched",
                    "admm_batched_jit")


@dataclass
class FLConfig:
    aggregator: str = "obcsaa"       # perfect | topk_aa | obcsaa
    # P2 solver, dispatched through the repro.sched registry (DESIGN.md
    # §10): all | enum | admm | greedy | admm_batched | greedy_batched.
    # Members of ENGINE_SCHEDULERS run fused inside the engine's scan.
    scheduler: str = "all"
    learning_rate: float = 0.1       # paper §V
    rounds: int = 300
    eval_every: int = 10
    seed: int = 0
    obcsaa: OBCSAAConfig = field(default_factory=OBCSAAConfig)
    const: AnalysisConstants = field(default_factory=AnalysisConstants)
    # topk_aa baseline: same κ budget as obcsaa over the FULL vector
    topk_dense: int = 1000
    # Beyond-paper: per-worker error feedback (Stich et al., paper ref [37]):
    # each worker keeps the residual of its top-κ sparsification and adds it
    # to the next round's gradient before compression.
    error_feedback: bool = False
    # Fading temporal correlation ρ of the Gauss-Markov fade recursion
    # (core/channel.py draw_fades); 0 is the paper's i.i.d. block-fading
    # per-round redraw, the §V setup.
    channel_rho: float = 0.0
    # Execution mode: "scan" = the jitted scan-over-rounds engine
    # (DESIGN.md §11), "host" = the per-round host reference loop (the
    # parity oracle; required for non-jittable schedulers like enum),
    # "auto" = scan when the scheduler supports it.
    mode: str = "auto"
    # Solver knobs for the batched P2 schedulers (None -> defaults)
    sched_cfg: Optional[SchedConfig] = None
    # Engine checkpointing (DESIGN.md §14): directory for eval-cadence
    # carry snapshots. ``run_sweep`` saves the full ``SweepCheckpoint``
    # (params/opt/fade/prev-β/warm-start/EF residuals + arms + t_next) at
    # every scan-chunk boundary; with ``ckpt_resume`` it restores the
    # latest step and continues bit-for-bit — the PRNG folds on absolute
    # round indices, so no generator state needs serializing.
    ckpt_dir: Optional[str] = None
    ckpt_resume: bool = False
    # Dual warm-starting (DESIGN.md §15): carry the ADMM multipliers of
    # round t's schedule in the scan state (next to prev-β) and seed round
    # t+1's solve with them. Only meaningful for the admm engine
    # schedulers; the solver re-initializes the primal every round, so the
    # per-round β is bitwise-unchanged (the serve-bench parity flag) — OFF
    # keeps the carry's ``sched_duals`` leaf None (pre-PR-8 trace).
    sched_warm_duals: bool = False
    # Measured-aggregation-error probe (repro.theory, DESIGN.md §12): emit
    # ‖ĝ−ḡ‖² per round next to the predicted Theorem-1 budget. Costs one
    # extra dense (U, D) reduction per round; OFF by default — disabled,
    # the round trace is exactly the pre-probe engine (bitwise-neutral).
    probe_agg_error: bool = False

    def engine_capable(self) -> bool:
        """Can every per-round decision run inside one jitted program?"""
        return (self.aggregator == "perfect"
                or self.scheduler in ENGINE_SCHEDULERS)

    def resolved_mode(self) -> str:
        if self.mode == "auto":
            return "scan" if self.engine_capable() else "host"
        if self.mode == "scan" and not self.engine_capable():
            raise ValueError(
                f"mode='scan' but scheduler {self.scheduler!r} is not "
                f"jittable (engine schedulers: {ENGINE_SCHEDULERS}); use "
                "mode='host' or a batched scheduler")
        return self.mode
