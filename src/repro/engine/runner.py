"""Chunked scan-over-rounds execution + vmap-over-arms sweeps
(DESIGN.md §11).

The engine runs rounds as ``lax.scan`` chunks cut at the eval cadence:
one jitted device call advances ``eval_every`` rounds (carry donated, so
params/opt/EF/warm-start buffers are reused in place), then the host
streams metrics (eval_fn, per-round scheduling stats) and launches the
next chunk. Chunk lengths take at most three distinct values (1,
``eval_every``, tail), so the jit cache stays bounded.

``run_sweep`` vmaps the same chunk over an ``Arms`` pytree: A experiment
arms (seeds × SNR × P^Max × lr) advance in ONE compiled program per
chunk — the fig1–fig5 sweep grids as a single device-resident computation
instead of sequential fig-script loops.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core.sparsify import flatten_pytree
from repro.engine.core import EngineFns, build_engine
from repro.engine.state import Arms, SweepCheckpoint, make_arms, single_arm
from repro.optim.optimizers import sgd
from repro.theory.bounds import ErrorBudget


def _donate():
    # buffer donation is a no-op (with a warning) on CPU; only ask for it
    # where the runtime honors it
    return (0,) if jax.default_backend() != "cpu" else ()


def eval_points(rounds: int, eval_every: int) -> List[int]:
    """Rounds after which the host evaluates — t % eval_every == 0 plus
    the final round, matching the historical trainer cadence."""
    pts = sorted({t for t in range(rounds) if t % eval_every == 0}
                 | {rounds - 1})
    return pts


def chunk_spans(rounds: int, eval_every: Optional[int]) -> List[tuple]:
    """(t0, n) scan chunks whose boundaries land on the eval points; one
    full-range chunk when metrics are not streamed."""
    if not eval_every:
        return [(0, rounds)]
    spans, t0 = [], 0
    for t in eval_points(rounds, eval_every):
        spans.append((t0, t - t0 + 1))
        t0 = t + 1
    return spans


class EngineRun:
    """One built engine + its jitted chunk programs (single arm or
    vmapped arms — same scan body either way)."""

    def __init__(self, cfg, loss_fn, params, worker_data, k_weights,
                 eval_fn: Optional[Callable] = None, optimizer=None):
        self.cfg = cfg
        self.worker_data = worker_data
        self.k_weights = jnp.asarray(k_weights, jnp.float32)
        self.eval_fn = eval_fn
        self.opt = optimizer or sgd()
        flat, unflatten = flatten_pytree(params)
        self.fns: EngineFns = build_engine(cfg, loss_fn, self.opt,
                                           int(flat.shape[0]),
                                           int(self.k_weights.shape[0]),
                                           unflatten)
        self._params0 = params
        self._chunk_cache: Dict[tuple, Callable] = {}

    # -- chunk programs ----------------------------------------------------

    def _chunk_fn(self, n: int, vmapped: bool) -> Callable:
        key = (n, vmapped)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        full_round = self.fns.full_round

        def chunk(state, arm, worker_data, k_weights, t0):
            def body(st, t):
                return full_round(st, arm, worker_data, k_weights, t)

            return jax.lax.scan(body, state, t0 + jnp.arange(n))

        fn = chunk
        if vmapped:
            fn = jax.vmap(chunk, in_axes=(0, 0, None, None, None))
        fn = jax.jit(fn, donate_argnums=_donate())
        self._chunk_cache[key] = fn
        return fn

    # -- single-arm run (the trainer's scan path) --------------------------

    def init(self, arm: Optional[Arms] = None):
        arm = arm if arm is not None else single_arm(self.cfg)
        return self.fns.init_state(self._params0, arm), arm

    def run_chunk(self, state, arm, t0: int, n: int, vmapped=False):
        """Advance ``n`` rounds from ``t0`` in one device call. Returns
        (state', RoundStats with (n,)-leading stat arrays)."""
        fn = self._chunk_fn(n, vmapped)
        return fn(state, arm, self.worker_data, self.k_weights,
                  jnp.int32(t0))

    # -- checkpointing (DESIGN.md §14) -------------------------------------

    def sweep_template(self, arms: Arms) -> SweepCheckpoint:
        """Shape/dtype template of the sweep checkpoint — built with
        ``eval_shape`` (no state allocation), structurally identical to
        what ``run_sweep`` saves, so ``checkpoint.restore`` can validate
        leaf-by-leaf before touching the carry."""
        state = jax.eval_shape(
            jax.vmap(lambda a: self.fns.init_state(self._params0, a)), arms)
        return SweepCheckpoint(state=state, arms=arms,
                               t_next=jnp.zeros((), jnp.int32))

    def _restore_sweep(self, ckpt_dir: str, arms: Arms):
        """(state, t_start) from the latest checkpoint step, or None.
        The saved arms must match the requested ones bitwise — a resumed
        sweep under different seeds/SNR/P^Max/lr would silently produce a
        chimera trajectory."""
        step = checkpoint.latest_step(ckpt_dir)
        if step is None:
            return None
        ck = checkpoint.restore(ckpt_dir, step, self.sweep_template(arms))
        for name, saved, want in zip(Arms._fields, ck.arms, arms):
            if not np.array_equal(np.asarray(saved), np.asarray(want)):
                raise ValueError(
                    f"checkpoint {ckpt_dir!r} step {step} was written "
                    f"under different arms (field {name!r} differs); "
                    f"resuming would mix trajectories — pass the arms the "
                    f"sweep was started with")
        return ck.state, int(ck.t_next)

    # -- vmapped arms sweep ------------------------------------------------

    def run_sweep(self, arms: Arms, rounds: Optional[int] = None,
                  eval_every: Optional[int] = None, *,
                  ckpt_dir: Optional[str] = None,
                  resume: Optional[bool] = None, mesh=None) -> Dict:
        """Run A arms for ``rounds`` rounds as vmapped scan chunks.

        Returns a dict of host arrays: per-round scheduling trajectories
        ``n_scheduled``/``b_t`` with shape (A, rounds) (dense — every
        round, DESIGN.md §11), the predicted Theorem-1 ``budget``
        (``ErrorBudget`` of (A, rounds) arrays) with its ``rt_bound``
        total (repro.theory, DESIGN.md §12) — the whole seeds×SNR grid's
        bounds from the same compiled program; eq. 19 models the 1-bit CS
        pipeline, so these keys are present for ``aggregator="obcsaa"``
        only — plus ``agg_err`` when the
        measured-error probe is on, eval streams ``eval_rounds``/``loss``/
        ``accuracy`` when an eval_fn is present, and the final per-arm
        ``params`` (stacked pytree) + ``state``.

        Checkpointing (DESIGN.md §14): with ``ckpt_dir`` (or
        ``cfg.ckpt_dir``) the full ``SweepCheckpoint`` is saved at every
        scan-chunk boundary (the eval cadence); ``resume`` (or
        ``cfg.ckpt_resume``) restores the latest step and continues —
        bit-for-bit identical to the uninterrupted sweep, because the
        post-boundary chunk programs and their absolute-round PRNG folds
        are the same in both runs. Stat/eval streams then cover only
        [t_start, rounds) — ``out["t_start"]`` says where they begin.
        ``mesh``: optional device mesh; state/arms are placed with the
        leading arm axis sharded over the worker axes
        (``dist.infer_batch_sharding``) so A-arm sweeps spread over
        devices."""
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        eval_every = eval_every if eval_every is not None \
            else (cfg.eval_every if self.eval_fn else None)
        ckpt_dir = ckpt_dir if ckpt_dir is not None else cfg.ckpt_dir
        resume = cfg.ckpt_resume if resume is None else resume
        A = int(arms.noise_var.shape[0])
        state = jax.vmap(lambda a: self.fns.init_state(self._params0, a)
                         )(arms)
        t_start = 0
        if resume:
            if not ckpt_dir:
                raise ValueError("run_sweep(resume=True) needs ckpt_dir "
                                 "(or FLConfig.ckpt_dir)")
            restored = self._restore_sweep(ckpt_dir, arms)
            if restored is not None:
                state, t_start = restored
        if mesh is not None:
            from repro.dist.sharding import infer_batch_sharding
            state = jax.device_put(state, infer_batch_sharding(state, mesh))
            arms = jax.device_put(arms, infer_batch_sharding(arms, mesh))
        eval_v = jax.vmap(self.eval_fn) if self.eval_fn else None
        n_sched, b_ts, losses, accs, eval_ts = [], [], [], [], []
        budgets, errs = [], []
        for t0, n in chunk_spans(rounds, eval_every):
            if t0 + n <= t_start:
                continue                    # chunk fully covered by resume
            if t0 < t_start:
                raise ValueError(
                    f"checkpoint t_next={t_start} does not land on a chunk "
                    f"boundary for rounds={rounds}, eval_every={eval_every} "
                    f"— resume must use the cadence the sweep was saved "
                    f"with (boundary before it: t0={t0})")
            state, stats = self.run_chunk(state, arms, t0, n, vmapped=True)
            # stats leaves: (A, n) -> per-round trajectory slabs
            n_sched.append(np.asarray(stats.n_scheduled))
            b_ts.append(np.asarray(stats.b_t))
            if stats.budget is not None:
                budgets.append(tuple(np.asarray(x) for x in stats.budget))
            if stats.agg_err is not None:
                errs.append(np.asarray(stats.agg_err))
            if eval_v is not None:
                loss, acc = eval_v(state.params)
                losses.append(np.asarray(loss))
                accs.append(np.asarray(acc))
                eval_ts.append(t0 + n - 1)
            if ckpt_dir:
                checkpoint.save(ckpt_dir, t0 + n, SweepCheckpoint(
                    state=state, arms=arms,
                    t_next=jnp.asarray(t0 + n, jnp.int32)))

        def cat(parts, dtype=np.float32):
            return (np.concatenate(parts, axis=1) if parts
                    else np.zeros((A, 0), dtype))

        out = {"n_scheduled": cat(n_sched, np.int32), "b_t": cat(b_ts),
               "state": state, "params": state.params, "arms": arms,
               "t_start": t_start}
        assert out["n_scheduled"].shape == (A, rounds - t_start)
        if budgets:
            budget = ErrorBudget(*(np.concatenate(parts, axis=1)
                                   for parts in zip(*budgets)))
            out["budget"] = budget
            out["rt_bound"] = np.asarray(budget.rt())
            assert out["rt_bound"].shape == (A, rounds - t_start)
        if errs:
            out["agg_err"] = np.concatenate(errs, axis=1)
        if eval_v is not None and losses:
            out["eval_rounds"] = np.asarray(eval_ts)
            out["loss"] = np.stack(losses, axis=1)       # (A, n_evals)
            out["accuracy"] = np.stack(accs, axis=1)
        return out


def run_sweep(cfg, loss_fn, params, worker_data, k_weights, *,
              arms: Optional[Arms] = None, eval_fn=None, optimizer=None,
              rounds: Optional[int] = None,
              eval_every: Optional[int] = None,
              ckpt_dir: Optional[str] = None,
              resume: Optional[bool] = None, mesh=None, **arm_axes) -> Dict:
    """One-call sweep: build the engine, broadcast ``arm_axes`` (seeds /
    noise_var / p_max / lr sequences) into an ``Arms`` pytree and run the
    scan × vmap grid. See ``EngineRun.run_sweep`` for the result dict and
    the checkpoint/resume semantics (DESIGN.md §14)."""
    run = EngineRun(cfg, loss_fn, params, worker_data, k_weights,
                    eval_fn=eval_fn, optimizer=optimizer)
    arms = arms if arms is not None else make_arms(cfg, **arm_axes)
    return run.run_sweep(arms, rounds=rounds, eval_every=eval_every,
                         ckpt_dir=ckpt_dir, resume=resume, mesh=mesh)
