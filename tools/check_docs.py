"""Docs consistency checker (the CI docs job).

Four checks, exit non-zero on any failure:

1. Internal markdown links in README.md and DESIGN.md resolve: relative
   link targets exist on disk; ``#anchor`` fragments match a heading in
   the target file (GitHub slugging, good enough for our headings).
2. ``DESIGN.md §N`` references cited in docstrings/comments across
   ``src/``, ``tests/``, ``benchmarks/`` and ``tools/`` point at sections
   that actually exist in DESIGN.md.
3. DESIGN.md § numbering is stable: sections are unique and contiguous
   from §1 (the docstring cross-reference contract, DESIGN.md preamble).
4. The subsystem sections (``REQUIRED_CITED``: the worker-axes mapping §3,
   chunked-Φ §4, decode §9, sched §10, engine §11, theory §12, packed
   1-bit codec §13, zoo sharding + checkpoint/restore §14, the serve
   loop §15, real sharded backward passes §16) are each cited from code
   at least once — a renumbering or a subsystem losing its docs trail
   fails CI.

  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", ROOT / "DESIGN.md"]
CODE_DIRS = ["src", "tests", "benchmarks", "tools"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_RE = re.compile(r"^##\s+§(\d+)", re.MULTILINE)
# §N references like "DESIGN.md §4", "DESIGN §9", "(DESIGN.md §4/§9)",
# plus bare continuation refs "§4" inside the same parenthetical
DESIGN_REF_RE = re.compile(r"DESIGN(?:\.md)?\s+§(\d+)((?:[/,]\s*§\d+)*)")
EXTRA_REF_RE = re.compile(r"§(\d+)")
# subsystem sections that must stay cited from code (check 4)
REQUIRED_CITED = {3, 4, 9, 10, 11, 12, 13, 14, 15, 16, 17}


def github_slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"[\s]+", "-", s)


def check_links(errors: list):
    for doc in DOCS:
        text = doc.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            tpath = (doc.parent / path_part if path_part else doc)
            if not tpath.exists():
                errors.append(f"{doc.name}: broken link target {target!r}")
                continue
            if anchor and tpath.suffix == ".md":
                headings = re.findall(r"^#+\s+(.*)$", tpath.read_text(),
                                      re.MULTILINE)
                if anchor not in {github_slug(h) for h in headings}:
                    errors.append(f"{doc.name}: anchor {target!r} matches no "
                                  f"heading in {tpath.name}")


def design_sections() -> set:
    return {int(n) for n in SECTION_RE.findall(
        (ROOT / "DESIGN.md").read_text())}


def check_section_numbering(errors: list):
    nums = SECTION_RE.findall((ROOT / "DESIGN.md").read_text())
    as_int = [int(n) for n in nums]
    if len(as_int) != len(set(as_int)):
        errors.append(f"DESIGN.md: duplicate § numbers: {sorted(as_int)}")
    if sorted(as_int) != list(range(1, len(as_int) + 1)):
        errors.append("DESIGN.md: § numbering not contiguous from §1: "
                      f"{sorted(as_int)}")


def check_design_refs(errors: list):
    known = design_sections()
    cited = set()
    for d in CODE_DIRS:
        for path in (ROOT / d).rglob("*.py"):
            text = path.read_text()
            for m in DESIGN_REF_RE.finditer(text):
                refs = [int(m.group(1))]
                refs += [int(x) for x in EXTRA_REF_RE.findall(m.group(2))]
                cited.update(refs)
                for ref in refs:
                    if ref not in known:
                        errors.append(
                            f"{path.relative_to(ROOT)}: cites DESIGN.md "
                            f"§{ref}, which does not exist "
                            f"(have §{sorted(known)})")
    for ref in sorted(REQUIRED_CITED - cited):
        errors.append(f"DESIGN.md §{ref} is a subsystem section but no "
                      f"code cites it (REQUIRED_CITED)")


def main() -> int:
    errors: list = []
    check_links(errors)
    check_section_numbering(errors)
    check_design_refs(errors)
    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_refs = sum(len(DESIGN_REF_RE.findall(p.read_text()))
                 for d in CODE_DIRS for p in (ROOT / d).rglob("*.py"))
    print(f"check_docs: OK ({len(design_sections())} DESIGN sections, "
          f"{n_refs} § citations verified, links resolved)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
